"""Render §Perf summary: baseline vs v2 vs v3opt for the three pairs."""
import json, os

def get(arch, shape, tag, mesh="single"):
    p = f"experiments/artifacts/{arch}__{shape}__{mesh}__{tag}.json"
    if not os.path.exists(p): return None
    a = json.load(open(p))
    return a if a.get("status") == "ok" else None

PAIRS = [("deepseek-v3-671b", "train_4k"),
         ("deepseek-v3-671b", "prefill_32k"),
         ("qwen2.5-32b", "decode_32k")]
TAGS = ["baseline", "v2", "v3opt", "opt_microbatch", "opt_rematdots"]

print("| pair | variant | t_compute | t_memory | t_collective | dominant | useful | mem/chip |")
print("|---|---|---|---|---|---|---|---|")
for arch, shape in PAIRS:
    for tag in TAGS:
        a = get(arch, shape, tag)
        if a is None: continue
        dom = max(a["t_compute"], a["t_memory"], a["t_collective"])
        print(f"| {arch}×{shape} | {tag} | {a['t_compute']:.2e} | {a['t_memory']:.2e} "
              f"| {a['t_collective']:.2e} | {a['bottleneck']} ({dom:.2e}s) "
              f"| {a['useful_flops_ratio']:.2f} | {a['peak_memory_per_chip']/2**30:.0f}G |")
