"""Transport byte accounting, compression, parallel windows, runtime model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.runtime_model import (WorkloadSpec, runtime_fl, runtime_sfl,
                                      runtime_sl, runtime_slp, runtime_tl)
from repro.core.transport import NetworkModel, Transport, payload_bytes


def test_payload_bytes():
    tree = {"a": jnp.zeros((10, 4), jnp.float32), "b": jnp.zeros((3,), jnp.int8)}
    assert payload_bytes(tree) == 10 * 4 * 4 + 3


def test_transport_accounting_and_clock():
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.1))
    tr.send("x", jnp.zeros((250_000,), jnp.float32))   # 1 MB -> 1.1 s
    assert tr.bytes_sent["x"] == 1_000_000
    assert abs(tr.clock_s - 1.1) < 1e-9


def test_parallel_window_takes_max():
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0))
    with tr.parallel():
        tr.send("a", jnp.zeros((250_000,), jnp.float32))   # 1.0 s
        tr.send("b", jnp.zeros((125_000,), jnp.float32))   # 0.5 s
    assert abs(tr.clock_s - 1.0) < 1e-9                    # overlap: max not sum


def test_compression_reduces_bytes():
    tr_plain = Transport()
    tr_comp = Transport(compress_activations=True)
    x = {"acts": jnp.ones((256, 64), jnp.float32)}
    tr_plain.send("t", x, compressible=True)
    got = tr_comp.send("t", x, compressible=True)
    assert tr_comp.bytes_sent["t"] < tr_plain.bytes_sent["t"] / 3
    # §5.2: lossy but close
    np.testing.assert_allclose(np.asarray(got["acts"]), np.ones((256, 64)),
                               atol=0.02)


@pytest.fixture
def spec():
    return WorkloadSpec(
        n_nodes=20, samples_per_node=500, batch_size=50,
        model_bytes=40e6, first_layer_bytes_per_sample=4096,
        logits_bytes_per_sample=40, first_layer_param_bytes=1e5,
        flops_per_sample_fwd=1e8, flops_per_sample_bwd=2e8)


def test_runtime_ordering_matches_paper_table2(spec):
    """Paper Table 2: TL < FL/SFL < SL < SL+ (20 nodes)."""
    t = {"FL": runtime_fl(spec), "SL": runtime_sl(spec),
         "SL+": runtime_slp(spec), "SFL": runtime_sfl(spec),
         "TL": runtime_tl(spec, cache_model=True)}
    assert t["TL"] < t["FL"]
    assert t["TL"] < t["SFL"]
    assert t["SFL"] < t["SL"] < t["SL+"]


def test_tl_compression_and_caching_help(spec):
    base = runtime_tl(spec)
    cached = runtime_tl(spec, cache_model=True)
    comp = runtime_tl(spec, cache_model=True, compressed=True)
    # pipelined: once the server recompute is the critical path, further
    # wire savings can't reduce the round below it (comp == cached)
    assert comp <= cached < base
    # unpipelined (pure eq. 19 additive form): strictly ordered
    b2 = runtime_tl(spec, pipelined=False)
    c2 = runtime_tl(spec, cache_model=True, pipelined=False)
    k2 = runtime_tl(spec, cache_model=True, compressed=True, pipelined=False)
    assert k2 < c2 < b2


def test_sl_scales_linearly_with_nodes(spec):
    import dataclasses
    t20 = runtime_sl(spec)
    t40 = runtime_sl(dataclasses.replace(spec, n_nodes=40))
    assert t40 > 1.8 * t20      # sequential methods blow up with node count
