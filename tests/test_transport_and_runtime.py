"""Transport byte accounting, compression, parallel windows, fault lanes,
runtime model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faults import FaultInjector, FaultSpec, VisitDropped
from repro.core.runtime_model import (WorkloadSpec, runtime_fl, runtime_sfl,
                                      runtime_sl, runtime_slp, runtime_tl)
from repro.core.transport import (LaneSpec, NetworkModel, Transport,
                                  WirePolicy, payload_bytes)


def test_payload_bytes():
    tree = {"a": jnp.zeros((10, 4), jnp.float32), "b": jnp.zeros((3,), jnp.int8)}
    assert payload_bytes(tree) == 10 * 4 * 4 + 3


def test_transport_accounting_and_clock():
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.1))
    tr.send("x", jnp.zeros((250_000,), jnp.float32))   # 1 MB -> 1.1 s
    assert tr.bytes_sent["x"] == 1_000_000
    assert abs(tr.clock_s - 1.1) < 1e-9


def test_parallel_window_takes_max():
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0))
    with tr.parallel():
        tr.send("a", jnp.zeros((250_000,), jnp.float32))   # 1.0 s
        tr.send("b", jnp.zeros((125_000,), jnp.float32))   # 0.5 s
    assert abs(tr.clock_s - 1.0) < 1e-9                    # overlap: max not sum


def test_overlap_lanes_cost_max_of_lane_totals():
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0))
    with tr.overlap() as ov:
        with ov.lane("bp"):
            tr.tick(2.0)                                   # BP of batch k
        with ov.lane("visits"):
            tr.send("a", jnp.zeros((250_000,), jnp.float32))   # 1.0 s
            tr.send("a", jnp.zeros((125_000,), jnp.float32))   # 0.5 s (sum)
    assert abs(tr.clock_s - 2.0) < 1e-9                    # max(2.0, 1.5)
    rec = tr.window_log[-1]
    assert rec.kind == "overlap"
    assert abs(rec.lanes["bp"] - 2.0) < 1e-9
    assert abs(rec.lanes["visits"] - 1.5) < 1e-9
    assert rec.by_tag == {"a": 1_500_000}                  # per-window bytes


def test_overlap_strict_lane_keeps_ticks_serial():
    """ticks=False (strict-mode prefetch): compute stays on the serial
    clock, only the lane's transfers overlap the other lane."""
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0))
    with tr.overlap() as ov:
        with ov.lane("visits", ticks=False):
            tr.tick(1.0)                                   # -> serial clock
            tr.send("a", jnp.zeros((125_000,), jnp.float32))   # 0.5 s lane
        with ov.lane("bp"):
            tr.tick(0.2)
    assert abs(tr.clock_s - (1.0 + 0.5)) < 1e-9            # 1.0 + max(.5,.2)


def test_parallel_window_nested_in_lane():
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0))
    with tr.overlap() as ov:
        with ov.lane("visits"):
            with tr.parallel():                            # max inside lane
                tr.send("a", jnp.zeros((250_000,), jnp.float32))   # 1.0 s
                tr.send("a", jnp.zeros((125_000,), jnp.float32))   # 0.5 s
        with ov.lane("bp"):
            tr.tick(0.4)
    assert abs(tr.clock_s - 1.0) < 1e-9                    # max(max(1,.5), .4)
    # tag attribution survives the nested window: the enclosing overlap
    # record still sees the real tags, not a synthetic "<window>"
    rec = tr.window_log[-1]
    assert rec.kind == "overlap" and rec.by_tag == {"a": 1_500_000}


def test_pipelined_epoch_same_bytes_smaller_clock():
    """End-to-end on the orchestrator: overlap changes clock, never bytes —
    per-tag accounting identical, simulated clock strictly smaller."""
    import jax
    from repro.configs.paper_models import DATRET
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.plan import PlanSpec
    from repro.models.small import SmallModel
    from repro.optim import sgd

    def build(pipelined):
        model = SmallModel(DATRET)
        r = np.random.default_rng(0)
        nodes = [TLNode(i, model,
                        r.normal(size=(24,) + DATRET.in_shape).astype(np.float32),
                        r.integers(0, DATRET.n_classes, 24))
                 for i in range(2)]
        orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                              batch_size=16, plan=PlanSpec(seed=0),
                              pipelined=pipelined,
                              compute_time_fn=lambda k: 1e-4 * k,
                              bp_time_fn=lambda n: 5e-4 * n)
        orch.initialize(jax.random.PRNGKey(0))
        return orch

    serial, piped = build(False), build(True)
    for _ in range(2):
        serial.train_epoch()
        piped.train_epoch()
    assert serial.transport.bytes_sent == piped.transport.bytes_sent
    assert serial.transport.n_messages == piped.transport.n_messages
    assert piped.transport.clock_s < serial.transport.clock_s


# ------------------------------------------------------------- fault lanes
def _mb_transport(**kw):
    return Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6,
                                          rtt_s=0.0), **kw)


def _first_key(injector, kind, attempts=2000):
    """A key whose seeded verdict is ``kind`` (deterministic hunt)."""
    for a in range(attempts):
        if injector.decide((0, 0, 0, a)).kind == kind:
            return (0, 0, 0, a)
    raise AssertionError(f"no {kind} verdict in {attempts} keys")


def test_fault_lane_straggle_multiplies_clock_never_bytes():
    inj = FaultInjector(FaultSpec(straggle_prob=1.0, straggle_factor=3.0))
    tr = _mb_transport(faults=inj)
    with tr.fault_lane((0, 0, 0, 0)) as out:
        assert out.kind == "straggle"
        tr.send("a", jnp.zeros((250_000,), jnp.float32))     # 1.0 s base
        tr.tick(0.5)                                         # compute slows too
    assert abs(tr.clock_s - 3.0 * 1.5) < 1e-9
    assert tr.bytes_sent["a"] == 1_000_000                   # bytes untouched
    rec = tr.window_log[-1]
    assert rec.kind == "fault:straggle" and rec.meta["factor"] == 3.0
    assert rec.nbytes == 1_000_000 and abs(rec.clock_s - 4.5) < 1e-9


def test_fault_lane_drop_charges_then_raises():
    """A dropped attempt is charged (the payload burned wire time before it
    was lost) and raises at lane exit; the window_log fault record carries
    exactly the wasted bytes/clock."""
    inj = FaultInjector(FaultSpec(drop_prob=0.9, seed=7))
    tr = _mb_transport(faults=inj)
    key = _first_key(inj, "drop")
    with pytest.raises(VisitDropped):
        with tr.fault_lane(key):
            tr.send("t", jnp.zeros((250_000,), jnp.float32))
    assert tr.bytes_sent["t"] == 1_000_000
    assert abs(tr.clock_s - 1.0) < 1e-9
    rec = tr.window_log[-1]
    assert rec.kind == "fault:drop" and rec.by_tag == {"t": 1_000_000}
    assert tr.fault_log[-1].key == key and tr.fault_log[-1].nbytes == 1_000_000


def test_retry_bytes_grow_by_exactly_the_retried_payload():
    """The satellite invariant: after a retry loop, total bytes equal the
    clean send plus one payload per dropped attempt — derivable from
    window_log, never silently double-counted."""
    payload = jnp.zeros((1000,), jnp.float32)                # 4000 B
    clean = _mb_transport()
    clean.send("t", payload)

    inj = FaultInjector(FaultSpec(drop_prob=0.6, seed=5))  # drops twice, then ok
    tr = _mb_transport(faults=inj)
    attempts = 0
    while True:
        try:
            with tr.fault_lane((0, 0, 0, attempts)):
                tr.send("t", payload)
            break
        except VisitDropped:
            attempts += 1
    drops = [r for r in tr.window_log if r.kind == "fault:drop"]
    assert len(drops) == attempts == 2
    assert tr.bytes_sent["t"] == clean.bytes_sent["t"] * (attempts + 1)
    assert (tr.bytes_sent["t"]
            == clean.bytes_sent["t"] + sum(r.nbytes for r in drops))
    # every attempt's transfer also advanced the clock
    assert abs(tr.clock_s - (attempts + 1) * clean.clock_s) < 1e-9


def test_fault_lane_passthrough_without_injector():
    tr = _mb_transport()
    with tr.fault_lane((0, 0, 0, 0)) as out:
        tr.send("a", jnp.zeros((250_000,), jnp.float32))
    assert out.kind == "ok"
    assert tr.window_log == [] and tr.fault_log == []
    assert abs(tr.clock_s - 1.0) < 1e-9


def test_compression_reduces_bytes():
    tr_plain = Transport()
    tr_comp = Transport(wire=WirePolicy({"t": LaneSpec("int8")}))
    x = {"acts": jnp.ones((256, 64), jnp.float32)}
    tr_plain.send("t", x, compressible=True)
    got = tr_comp.send("t", x, compressible=True)
    assert tr_comp.bytes_sent["t"] < tr_plain.bytes_sent["t"] / 3
    # raw_bytes keeps the uncompressed total on both transports
    assert tr_comp.raw_bytes["t"] == tr_plain.bytes_sent["t"]
    # each compressed send logs a wire:* record with the raw/wire ratio
    (rec,) = [r for r in tr_comp.window_log if r.kind == "wire:int8"]
    assert rec.nbytes == tr_comp.bytes_sent["t"]
    assert rec.meta["raw_bytes"] == tr_comp.raw_bytes["t"]
    assert rec.meta["ratio"] > 3
    # §5.2: lossy but close
    np.testing.assert_allclose(np.asarray(got["acts"]), np.ones((256, 64)),
                               atol=0.02)


def test_wire_policy_rejects_lossy_model_lane():
    with pytest.raises(ValueError, match="never quantize"):
        WirePolicy({"model": LaneSpec("int8")})
    with pytest.raises(ValueError, match="unknown wire codec"):
        LaneSpec("int4")
    with pytest.raises(ValueError, match="requires a lossy codec"):
        LaneSpec("off", error_feedback=True)
    assert WirePolicy.visits("off") is None
    pol = WirePolicy.visits("fp8", error_feedback=True)
    assert pol.lane("activations_grads").codec == "fp8"
    assert pol.lane("model").codec == "off"


@pytest.fixture
def spec():
    return WorkloadSpec(
        n_nodes=20, samples_per_node=500, batch_size=50,
        model_bytes=40e6, first_layer_bytes_per_sample=4096,
        logits_bytes_per_sample=40, first_layer_param_bytes=1e5,
        flops_per_sample_fwd=1e8, flops_per_sample_bwd=2e8)


def test_runtime_ordering_matches_paper_table2(spec):
    """Paper Table 2: TL < FL/SFL < SL < SL+ (20 nodes)."""
    t = {"FL": runtime_fl(spec), "SL": runtime_sl(spec),
         "SL+": runtime_slp(spec), "SFL": runtime_sfl(spec),
         "TL": runtime_tl(spec, cache_model=True)}
    assert t["TL"] < t["FL"]
    assert t["TL"] < t["SFL"]
    assert t["SFL"] < t["SL"] < t["SL+"]


def test_tl_compression_and_caching_help(spec):
    base = runtime_tl(spec)
    cached = runtime_tl(spec, cache_model=True)
    comp = runtime_tl(spec, cache_model=True, compressed=True)
    # pipelined: once the server recompute is the critical path, further
    # wire savings can't reduce the round below it (comp == cached)
    assert comp <= cached < base
    # unpipelined (pure eq. 19 additive form): strictly ordered
    b2 = runtime_tl(spec, pipelined=False)
    c2 = runtime_tl(spec, cache_model=True, pipelined=False)
    k2 = runtime_tl(spec, cache_model=True, compressed=True, pipelined=False)
    assert k2 < c2 < b2


def test_runtime_tl_fault_knobs_expand_the_clock(spec):
    """Eq. 19 with the fault-expansion multiplier: faults can only slow the
    round down, and only through the visit phase (client + wire) — the
    orchestrator BP term is untouched, so expansion is sub-linear in the
    round total."""
    base = runtime_tl(spec, pipelined=False)
    dropped = runtime_tl(spec, pipelined=False, drop_prob=0.25)
    straggled = runtime_tl(spec, pipelined=False,
                           straggle_prob=0.5, straggle_factor=4.0)
    both = runtime_tl(spec, pipelined=False, drop_prob=0.25,
                      straggle_prob=0.5, straggle_factor=4.0)
    assert base < dropped < both and base < straggled < both
    # the BP/server term is fault-free: total grows slower than the raw
    # expansion factor (here 1/(1-0.25) = 4/3)
    assert dropped < base * (4 / 3)


def test_sl_scales_linearly_with_nodes(spec):
    import dataclasses
    t20 = runtime_sl(spec)
    t40 = runtime_sl(dataclasses.replace(spec, n_nodes=40))
    assert t40 > 1.8 * t20      # sequential methods blow up with node count
