"""Hierarchical TL: the planner/executor split and the two-tier tree.

The acceptance grid for ``repro.core.plan`` + ``repro.core.hierarchy``:

* **Lossless merge**: a 2-subtree ``HierarchicalOrchestrator`` over uneven
  node splits matches the flat orchestrator's parameter trajectory to a few
  float32 ULPs, fused AND eager — the per-subtree contribution sums
  reassociate the same tail-vjp arithmetic, nothing more.
* **Planner purity / shim pin**: ``TLOrchestrator.build_plan`` is a thin
  shim over ``FlatPlanner`` and returns byte-identical plans for the same
  ``(seed, epoch)`` — pickled-bytes equality against the direct
  Algorithm 1 call.
* **Exactly-once trees** (property): ``TreePlanner`` partitions nodes and
  every batch's positions exactly once across children, for ragged node
  counts including single-node subtrees.
* **Kwarg regrouping**: legacy planning kwargs (``seed=``, ``replicas=``,
  ``recovery=``) still work but warn; mixing them with ``plan=PlanSpec``
  is an error; the new spelling is warning-free.
* **Window accounting** (satellite bugfix): per-subtree lane bytes sum
  into the root ledger exactly — ``WindowRecord.lane_bytes`` reconciles
  against ``by_tag`` per overlap record, and the serialized merge bytes
  appear once in ``bytes_sent`` and in no lane.
* **Eq. 19 two-tier branch**: ``runtime_tl(spec, hierarchy=s)`` predicts
  the measured transport clock of a real simulated epoch to float
  tolerance (rtt=0 alignment regime, same as the flat eq. 19 test).
* **Engine fan-out**: ``Engine(mode="sim", hierarchy=s)`` is a faithful
  facade (ULP-equal to the flat sim engine) and pins its validation
  errors.
"""
import pickle
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.paper_models import DATRET
from repro.core.faults import RecoveryPolicy
from repro.core.hierarchy import HierarchicalOrchestrator
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import FlatPlanner, PlanSpec, TraversalPlan, TreePlanner
from repro.core.runtime_model import WorkloadSpec, runtime_tl
from repro.core.transport import NetworkModel, Transport, payload_bytes
from repro.core.virtual_batch import IndexRange, create_virtual_batches
from repro.models.small import SmallModel
from repro.optim import sgd

ULP_FACTOR = 16


def _make_nodes(model, sizes, seed, jit_visits):
    r = np.random.default_rng(seed)
    return [TLNode(i, model,
                   r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
                   r.integers(0, DATRET.n_classes, n), jit_visits=jit_visits)
            for i, n in enumerate(sizes)]


def _assert_ulp_equal(a, b):
    eps = np.finfo(np.float32).eps
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x = np.asarray(pa, dtype=np.float64)
        y = np.asarray(pb, dtype=np.float64)
        tol = ULP_FACTOR * eps * max(1.0, float(np.abs(x).max()))
        assert np.abs(x - y).max() <= tol, \
            f"hierarchy drifted {np.abs(x - y).max():.3e} > {tol:.3e}"


# ------------------------------------------------------------ lossless merge

@pytest.mark.parametrize("fused", [True, False], ids=["fused", "eager"])
@pytest.mark.parametrize("sizes", [[20, 12], [13, 8, 11]],
                         ids=["2nodes-uneven", "3nodes-uneven"])
def test_two_tier_matches_flat_to_ulp(sizes, fused):
    """2 subtrees over uneven splits: same losses, same accuracy, ULP-equal
    parameters after 2 epochs — the hierarchical merge is lossless."""
    model = SmallModel(DATRET)
    flat = TLOrchestrator(
        model, _make_nodes(model, sizes, 7, fused), sgd(0.05), Transport(),
        batch_size=16, plan=PlanSpec(seed=0), fused=fused)
    hier = HierarchicalOrchestrator(
        model, _make_nodes(model, sizes, 7, fused), sgd(0.05), Transport(),
        n_subtrees=2, batch_size=16, plan=PlanSpec(seed=0), fused=fused)
    key = jax.random.PRNGKey(3)
    flat.initialize(key)
    hier.initialize(key)
    for _ in range(2):
        sf = flat.train_epoch()
        sh = hier.train_epoch()
        assert len(sf) == len(sh)
        for a, b in zip(sf, sh):
            assert abs(float(a.loss) - float(b.loss)) < 1e-6
            assert abs(float(a.acc) - float(b.acc)) < 1e-9
    _assert_ulp_equal(flat.params, hier.params)


def test_single_node_subtrees_and_clamped_fanout():
    """n_subtrees beyond the node count clamps to one node per subtree and
    stays lossless (the 1-node-subtree degenerate case)."""
    model = SmallModel(DATRET)
    flat = TLOrchestrator(model, _make_nodes(model, [9, 7, 5], 2, True),
                          sgd(0.05), Transport(), batch_size=8,
                          plan=PlanSpec(seed=1))
    hier = HierarchicalOrchestrator(
        model, _make_nodes(model, [9, 7, 5], 2, True), sgd(0.05), Transport(),
        n_subtrees=8, batch_size=8, plan=PlanSpec(seed=1))
    assert hier.n_subtrees == 3
    key = jax.random.PRNGKey(0)
    flat.initialize(key)
    hier.initialize(key)
    flat.train_epoch()
    hier.train_epoch()
    _assert_ulp_equal(flat.params, hier.params)


# --------------------------------------------------------- planner/shim pins

def test_build_plan_shim_returns_byte_identical_plans():
    """The shim is pure and byte-identical to the direct Algorithm 1 call:
    same (seed, epoch) → pickle-equal VirtualBatchPlan, for several epochs
    (resume/recovery re-derive plans instead of storing them)."""
    sizes = [13, 8, 11]
    model = SmallModel(DATRET)
    orch = TLOrchestrator(model, _make_nodes(model, sizes, 5, True),
                          sgd(0.05), Transport(), batch_size=16,
                          plan=PlanSpec(seed=4))
    ranges = [IndexRange(i, n) for i, n in enumerate(sizes)]
    for epoch in (0, 1, 2):
        p1 = orch.build_plan(epoch)
        p2 = orch.build_plan(epoch)
        assert isinstance(p1, TraversalPlan)
        assert pickle.dumps(p1.vb_plan) == pickle.dumps(p2.vb_plan)
        direct = create_virtual_batches(ranges, 16, seed=4 + epoch)
        assert pickle.dumps(p1.vb_plan) == pickle.dumps(direct)
        # flat planner → no children; provenance carried on the plan
        assert p1.children == () and (p1.seed, p1.epoch) == (4, epoch)


@given(sizes=st.lists(st.integers(1, 9), min_size=1, max_size=10),
       n_subtrees=st.integers(1, 12), batch=st.integers(1, 16),
       seed=st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_tree_planner_partitions_exactly_once(sizes, n_subtrees, batch, seed):
    """Property: for ragged node counts (including 1-node subtrees and
    n_subtrees > n_nodes), the tree's children partition the nodes exactly
    once, and every batch's positions land in exactly one child segment —
    samples are neither dropped nor double-covered."""
    ranges = [IndexRange(i, n) for i, n in enumerate(sizes)]
    planner = TreePlanner(n_subtrees)
    plan = planner.plan(ranges, batch_size=min(batch, sum(sizes)),
                        seed=seed, epoch=0)
    # nodes exactly once across children
    assert len(plan.children) == min(n_subtrees, len(sizes))
    flat_ids = [i for c in plan.children for i in c.node_ids]
    assert sorted(flat_ids) == [r.node_id for r in ranges]
    for c in plan.children:
        owned = set(c.node_ids)
        for vb in c.batches:
            assert all(s.node_id in owned for s in vb.traversal)
    # per-batch: children's traversals partition the root batch positions
    for vb in plan.batches:
        pos = [p for c in plan.children
               for s in c.batches[vb.batch_id].traversal
               for p in s.batch_positions.tolist()]
        assert sorted(pos) == list(range(vb.size))
        # child batches keep the root's global ids (the 1/N denominator)
        for c in plan.children:
            np.testing.assert_array_equal(
                c.batches[vb.batch_id].global_ids, vb.global_ids)


def test_tree_planner_rejects_bad_fanout_and_duplicates():
    with pytest.raises(ValueError, match="n_subtrees"):
        TreePlanner(0)
    with pytest.raises(ValueError, match="duplicate"):
        TreePlanner(2).partition([1, 1, 2])


# ------------------------------------------------------- kwarg regrouping

def test_legacy_planning_kwargs_warn_and_plan_spec_does_not():
    model = SmallModel(DATRET)
    nodes = _make_nodes(model, [10, 6], 1, True)
    for kw, match in ((dict(seed=3), "seed"),
                      (dict(replicas={}), "replicas"),
                      (dict(recovery=RecoveryPolicy()), "recovery")):
        with pytest.warns(DeprecationWarning, match=match):
            orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                                  batch_size=16, **kw)
    assert orch.recovery is not None
    # new spelling: warning-free, same resolved knobs
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                              plan=PlanSpec(seed=3, batch_size=16))
    assert orch.seed == 3 and orch.batch_size == 16
    assert isinstance(orch.planner, FlatPlanner)


def test_mixing_plan_spec_with_legacy_kwargs_is_an_error():
    model = SmallModel(DATRET)
    nodes = _make_nodes(model, [10, 6], 1, True)
    with pytest.raises(ValueError, match="twice"):
        TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                       plan=PlanSpec(seed=3), seed=3)
    with pytest.raises(TypeError, match="Planner"):
        TLOrchestrator(model, nodes, sgd(0.05), Transport(), plan=42)


def test_hierarchical_orchestrator_requires_tree_planner():
    model = SmallModel(DATRET)
    nodes = _make_nodes(model, [10, 6], 1, True)
    with pytest.raises(ValueError, match="TreePlanner"):
        HierarchicalOrchestrator(model, nodes, sgd(0.05), Transport(),
                                 plan=PlanSpec(planner=FlatPlanner()))


# --------------------------------------------------- nested window accounting

def test_subtree_lane_bytes_sum_into_root_ledger_without_double_count():
    """Satellite bugfix regression, on a 2-subtree tree: every overlap
    record's per-lane byte attribution sums to its ``by_tag`` exactly (a
    byte is attributed to one lane and no other), the visit/model bytes
    equal the flat run's, and the merge bytes are charged exactly once —
    outside every lane."""
    model = SmallModel(DATRET)
    sizes = [13, 8, 11, 9]
    flat = TLOrchestrator(model, _make_nodes(model, sizes, 7, True),
                          sgd(0.05), Transport(), batch_size=16,
                          plan=PlanSpec(seed=0))
    hier = HierarchicalOrchestrator(
        model, _make_nodes(model, sizes, 7, True), sgd(0.05), Transport(),
        n_subtrees=2, batch_size=16, plan=PlanSpec(seed=0))
    key = jax.random.PRNGKey(1)
    flat.initialize(key)
    hier.initialize(key)
    flat.train_epoch()
    hier.train_epoch()

    tr = hier.transport
    overlaps = [r for r in tr.window_log if r.kind == "overlap"]
    assert overlaps, "the hierarchy never opened a subtree overlap scope"
    for rec in overlaps:
        summed = {}
        for per_tag in rec.lane_bytes.values():
            for tag, nb in per_tag.items():
                summed[tag] = summed.get(tag, 0) + nb
        assert summed == rec.by_tag        # lanes sum to the window, exactly
        assert "contribution" not in rec.by_tag     # merge is outside lanes
    # per-subtree lanes move the same protocol bytes the flat run does
    for tag in ("model", "activations_grads"):
        assert hier.transport.bytes_sent[tag] == flat.transport.bytes_sent[tag]
    assert "contribution" not in flat.transport.bytes_sent
    # merge bytes: one gradient pytree + 8 B of stats scalars per
    # (batch, nonempty subtree), charged exactly once
    plan = TreePlanner(2).plan([IndexRange(i, n) for i, n in enumerate(sizes)],
                               batch_size=16, seed=0, epoch=0)
    per_contrib = payload_bytes(hier.params) + 8
    expected = sum(per_contrib
                   for vb in plan.batches for c in plan.children
                   if c.batches[vb.batch_id].traversal)
    assert hier.transport.bytes_sent["contribution"] == expected


# ------------------------------------------------ eq. 19 two-tier alignment

SIM_COMPUTE = 1e-4
SIM_BP = 5e-4


def _simulated(n_nodes, n_subtrees):
    """One-batch uniform-composition epoch on a zero-rtt 1 MB/s link —
    the byte-exact alignment regime of the existing eq. 19 test."""
    model = SmallModel(DATRET)
    nodes = _make_nodes(model, [2] * n_nodes, 0, True)
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0))
    kw = dict(batch_size=2 * n_nodes, plan=PlanSpec(seed=0),
              compute_time_fn=lambda m: SIM_COMPUTE * m,
              bp_time_fn=lambda m: SIM_BP * m)
    if n_subtrees is None:
        orch = TLOrchestrator(model, nodes, sgd(0.05), tr, **kw)
    else:
        orch = HierarchicalOrchestrator(model, nodes, sgd(0.05), tr,
                                        n_subtrees=n_subtrees, **kw)
    orch.initialize(jax.random.PRNGKey(0))
    orch.train_epoch()
    return orch


def _spec(n_nodes, model_bytes):
    client = 1e12
    return WorkloadSpec(
        n_nodes=n_nodes, samples_per_node=2, batch_size=2 * n_nodes,
        model_bytes=model_bytes,
        first_layer_bytes_per_sample=DATRET.hidden[0] * 4,
        logits_bytes_per_sample=DATRET.n_classes * 4,
        first_layer_param_bytes=(DATRET.in_shape[0] + 1)
        * DATRET.hidden[0] * 4,
        flops_per_sample_fwd=SIM_COMPUTE / 2 * client,
        flops_per_sample_bwd=SIM_COMPUTE / 2 * client,
        client_flops_per_s=client,
        server_flops_per_s=client * SIM_COMPUTE / SIM_BP,
        bandwidth_bytes_per_s=1e6, rtt_s=0.0)


@pytest.mark.parametrize("n_subtrees", [1, 3],
                         ids=["flat-baseline", "ragged-3-subtrees"])
def test_runtime_tl_two_tier_predicts_measured_clock(n_subtrees):
    """``runtime_tl(spec, hierarchy=s)`` reproduces the transport clock of
    a real simulated epoch: s=1 against the flat orchestrator, s=3 (ragged
    [3, 3, 2] split of 8 nodes) against the hierarchy."""
    orch = _simulated(8, None if n_subtrees == 1 else n_subtrees)
    spec = _spec(8, payload_bytes(orch.params))
    predicted = runtime_tl(spec, hierarchy=n_subtrees)
    assert abs(predicted - orch.transport.clock_s) < 1e-6


def test_runtime_tl_hierarchy_rejects_incompatible_knobs():
    spec = _spec(8, 1000)
    with pytest.raises(ValueError, match="two-tier"):
        runtime_tl(spec, hierarchy=2, compressed=True)
    with pytest.raises(ValueError, match="n_subtrees"):
        runtime_tl(spec, hierarchy=0)
    import dataclasses
    bad = dataclasses.replace(spec, batch_size=15)
    with pytest.raises(ValueError, match="multiple"):
        runtime_tl(bad, hierarchy=2)


# ----------------------------------------------------------- engine fan-out

def test_engine_sim_hierarchy_fanout_matches_flat():
    from repro.core.baselines import ShardData
    from repro.launch.engine import Engine

    r = np.random.default_rng(5)
    shards = [ShardData(
        r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
        r.integers(0, DATRET.n_classes, n)) for n in [13, 8, 11, 9]]
    model = SmallModel(DATRET)
    flat = Engine(model, DATRET, sgd(0.05), mode="sim", pipeline=False,
                  batch_size=16, seed=0).run(shards, epochs=1)
    hier = Engine(model, DATRET, sgd(0.05), mode="sim", pipeline=False,
                  batch_size=16, seed=0, hierarchy=2).run(shards, epochs=1)
    _assert_ulp_equal(flat.params, hier.params)
    np.testing.assert_allclose(flat.losses, hier.losses, rtol=1e-6)


def test_engine_hierarchy_validation_errors():
    from repro.launch.engine import Engine
    model = SmallModel(DATRET)
    with pytest.raises(ValueError, match=">= 0"):
        Engine(model, DATRET, sgd(0.05), mode="sim", hierarchy=-1)
    with pytest.raises(ValueError, match="pipeline=False"):
        Engine(model, DATRET, sgd(0.05), mode="sim", hierarchy=2)
    with pytest.raises(ValueError, match="simulator-only"):
        Engine(model, DATRET, sgd(0.05), object(), object(),
               mode="production", hierarchy=2)
