"""THE paper claim: TL's distributed update == the centralized (CL) update.

Validated at two levels:
  1. protocol level — orchestrator/node message passing produces exactly the
     CL gradient on each virtual batch (all three small-model families);
  2. production level — the pjit TL loss (remat-from-X^(1)) equals model.loss
     value and gradient for every assigned architecture family.
Also checks eq. 12 consistency (orchestrator-recomputed ∂L/∂X^(1) equals the
aggregated node-submitted first-layer gradients).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_models import CONVNET, DATRET, TINY_TRANSFORMER
from repro.core.node import TLNode, ce_sum
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.core.tl_step import tl_loss_fn
from repro.models import build_model
from repro.models.small import SmallModel
from repro.optim import sgd


def _make_nodes(model, cfg, sizes, rng):
    nodes = []
    for i, n in enumerate(sizes):
        if cfg.family == "transformer":
            x = rng.integers(0, cfg.vocab_size, (n, cfg.seq_len))
        else:
            x = rng.normal(size=(n,) + cfg.in_shape).astype(np.float32)
        y = rng.integers(0, cfg.n_classes, n)
        nodes.append(TLNode(i, model, x, y))
    return nodes


@pytest.mark.parametrize("cfg", [DATRET, CONVNET, TINY_TRANSFORMER],
                         ids=lambda c: c.name)
def test_protocol_matches_cl_gradient(cfg, rng):
    model = SmallModel(cfg)
    sizes = [13, 8, 11, 9]
    nodes = _make_nodes(model, cfg, sizes, rng)
    tr = Transport()
    orch = TLOrchestrator(model, nodes, sgd(0.05), tr, batch_size=16,
                          plan=PlanSpec(seed=0))
    orch.initialize(jax.random.PRNGKey(0))
    p0 = orch.params

    plan = orch.build_plan(0)
    vb = plan.batches[0]

    # centralized reference on the same virtual batch
    xs = np.concatenate([np.asarray(n.x) for n in nodes])
    ys = np.concatenate([np.asarray(n.y) for n in nodes])
    offs = np.cumsum([0] + sizes[:-1])
    rows = offs[plan.global_to_node[vb.global_ids]] \
        + plan.global_to_local[vb.global_ids]
    xb, yb = jnp.asarray(xs[rows]), jnp.asarray(ys[rows])
    cl_grads = jax.grad(
        lambda p: ce_sum(model.forward(p, xb), yb) / vb.size)(p0)

    for n in nodes:
        n.receive_model(p0)
    orch.cache_model_per_epoch = True
    stats = orch.train_batch(vb, {n.node_id: n for n in nodes})

    tl_grads = jax.tree.map(lambda a, b: (a - b) / 0.05, p0, orch.params)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), cl_grads, tl_grads)))
    assert err < 2e-5, f"TL gradient deviates from CL by {err}"
    assert stats.grad_consistency < 1e-5          # eq. 12


def test_protocol_training_matches_cl_trajectory(rng):
    """Several full TL epochs track a CL run on identical virtual batches."""
    cfg = DATRET
    model = SmallModel(cfg)
    sizes = [16, 16, 16, 16]
    nodes = _make_nodes(model, cfg, sizes, rng)
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=16, plan=PlanSpec(seed=0))
    orch.initialize(jax.random.PRNGKey(1))
    p_cl = orch.params
    st_cl = sgd(0.05).init(p_cl)

    xs = np.concatenate([np.asarray(n.x) for n in nodes])
    ys = np.concatenate([np.asarray(n.y) for n in nodes])
    offs = np.cumsum([0] + sizes[:-1])

    opt = sgd(0.05)
    for epoch in range(2):
        plan = orch.build_plan(epoch)
        for vb in plan.batches:
            rows = offs[plan.global_to_node[vb.global_ids]] \
                + plan.global_to_local[vb.global_ids]
            xb, yb = jnp.asarray(xs[rows]), jnp.asarray(ys[rows])
            g = jax.grad(lambda p: ce_sum(model.forward(p, xb), yb)
                         / vb.size)(p_cl)
            p_cl, st_cl = opt.update(p_cl, g, st_cl)
        orch.train_epoch()

    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p_cl, orch.params)))
    assert err < 5e-4, f"TL trajectory diverged from CL by {err}"


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "qwen2.5-32b",
                                  "recurrentgemma-9b", "mamba2-780m",
                                  "starcoder2-3b"])
def test_production_tl_loss_equals_model_loss(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    p = m.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(
            key, (2, cfg.frontend_tokens, cfg.d_model)) * 0.02

    l_tl = tl_loss_fn(m, cfg, "tl")(p, batch)
    l_cl = m.loss(p, batch)[0]
    assert abs(float(l_tl - l_cl)) < 1e-4

    g_tl = jax.grad(tl_loss_fn(m, cfg, "tl"))(p, batch)
    g_cl = jax.grad(lambda pp: m.loss(pp, batch)[0])(p)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), g_tl, g_cl)))
    assert err < 1e-4, f"remat-TL gradient deviates by {err}"
