"""Unified training-engine coverage (``repro.launch.engine``).

* Pipelined-vs-serial equivalence on the production pjit path: the 2-deep
  host->device prefetch queue is a pure transfer-timing reordering, so
  ``Engine(pipeline=True)`` must match the strictly batch-serial jit path
  to float32 ULP over >=4 steps — on the (2,2) debug mesh, the forced-8-
  device CPU host mesh, and a multi-pod-axes (pod, data, model) smoke cell.
* Roofline check: the sharded step's measured collective bytes (via
  ``repro.analysis.hlo_flops``) sit inside the band of
  ``predict_train_collective_bytes``'s no-CSE upper bound, and a (1,1)
  mesh measures exactly zero.
* CLI smoke: ``python -m repro.launch.train --steps 3 --mesh debug`` runs
  green (fast tier — the production entrypoint can never silently rot).
* Sim facade: ``Engine(mode="sim")`` reproduces the orchestrator paths.

Sharded cells run in subprocesses so the forced host-device count never
leaks into other tests.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ENV_BASE = dict(os.environ, PYTHONPATH=os.path.abspath("src"),
                 XLA_FLAGS="--xla_force_host_platform_device_count=8")

EQUIV_SCRIPT = textwrap.dedent("""
    import json, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                     synthetic_corpus)
    from repro.launch.engine import Engine
    from repro.launch.mesh import (make_debug_mesh, make_host_mesh,
                                   make_multipod_debug_mesh)
    from repro.models import build_model
    from repro.optim import adamw

    mesh = {"debug": lambda: make_debug_mesh(2, 2),
            "host": make_host_mesh,
            "multipod": make_multipod_debug_mesh}[os.environ["TEST_MESH"]]()
    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    B, S, STEPS = 8, 32, 4
    shape = InputShape("t", S, B, "train")

    def run(pipeline):
        docs = synthetic_corpus(4 * 16, S, cfg.vocab_size, seed=1)
        loader = VirtualBatchLoader(shard_corpus(docs, 4), B, seed=0)
        eng = Engine(model, cfg, adamw(3e-3, clip_norm=1.0), mesh, shape,
                     pipeline=pipeline)
        eng.init(jax.random.PRNGKey(0))
        res = eng.run(loader, steps=STEPS)
        return res

    a, b = run(True), run(False)
    assert a.steps == b.steps == STEPS
    eps = np.finfo(np.float32).eps
    worst = 0.0
    for pa, pb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        x = np.asarray(pa, np.float64)
        y = np.asarray(pb, np.float64)
        tol = 16 * eps * max(1.0, float(np.abs(x).max()))
        worst = max(worst, float(np.abs(x - y).max()) / tol)
    print("RESULT", json.dumps({
        "ulp_excess": worst,
        "loss_diff": float(np.abs(a.losses - b.losses).max()),
        "mesh_axes": list(mesh.axis_names)}))
""")


@pytest.mark.parametrize("mesh_kind", ["debug", "host", "multipod"])
def test_engine_pipelined_matches_serial(mesh_kind):
    """Engine(pipeline=True) == serial jit path to float32 ULP, per mesh.

    ``debug`` is the (2,2) debug mesh, ``host`` the forced-8-device CPU
    mesh, ``multipod`` the smallest (pod, data, model) mesh — the composite
    (pod, data) batch-axis smoke cell."""
    env = dict(_ENV_BASE, TEST_MESH=mesh_kind)
    proc = subprocess.run([sys.executable, "-c", EQUIV_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    data = json.loads(line.split("RESULT ")[1])
    assert data["ulp_excess"] <= 1.0, data
    assert data["loss_diff"] < 1e-6, data
    if mesh_kind == "multipod":
        assert data["mesh_axes"] == ["pod", "data", "model"]


REASSEMBLY_SCRIPT = textwrap.dedent("""
    import json, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                     synthetic_corpus)
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.optim import adamw

    mesh = make_debug_mesh(2, 2)              # data axis of 2: sharded perms
    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    B, S, STEPS = 8, 32, 4
    shape = InputShape("t", S, B, "train")

    def run(reassembly, pipeline):
        docs = synthetic_corpus(4 * 16, S, cfg.vocab_size, seed=1)
        loader = VirtualBatchLoader(shard_corpus(docs, 4), B, seed=0)
        eng = Engine(model, cfg, adamw(3e-3, clip_norm=1.0), mesh, shape,
                     pipeline=pipeline, reassembly=reassembly)
        eng.init(jax.random.PRNGKey(0))
        return eng.run(loader, steps=STEPS)

    a = run("xla", False)
    b = run("pallas", False)
    c = run("pallas", True)
    eps = np.finfo(np.float32).eps
    def ulp_excess(t1, t2):
        worst = 0.0
        for pa, pb in zip(jax.tree.leaves(t1.params),
                          jax.tree.leaves(t2.params)):
            x = np.asarray(pa, np.float64)
            y = np.asarray(pb, np.float64)
            tol = 16 * eps * max(1.0, float(np.abs(x).max()))
            worst = max(worst, float(np.abs(x - y).max()) / tol)
        return worst
    print("RESULT", json.dumps({
        "xla_vs_pallas": ulp_excess(a, b),
        "pallas_serial_vs_pipelined": ulp_excess(b, c),
        "loss_diff": float(np.abs(a.losses - b.losses).max())}))
""")


def test_engine_pallas_reassembly_matches_xla_sharded():
    """Production acceptance: on a mesh whose data axis shards the batch,
    the shard_map'd pallas reassembly matches the XLA-scatter path to
    float32 ULP over 4 steps (in practice bit-identically), and stays
    pipeline-invariant."""
    proc = subprocess.run([sys.executable, "-c", REASSEMBLY_SCRIPT],
                          env=_ENV_BASE, capture_output=True, text=True,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    data = json.loads(line.split("RESULT ")[1])
    assert data["xla_vs_pallas"] <= 1.0, data
    assert data["pallas_serial_vs_pipelined"] <= 1.0, data
    assert data["loss_diff"] < 1e-6, data


ROOFLINE_SCRIPT = textwrap.dedent("""
    import json, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.analysis.hlo_flops import analyze
    from repro.analysis.roofline import predict_train_collective_bytes
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.core.tl_step import make_train_step, train_shardings
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.optim import sgd

    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = sgd(0.1)
    st = opt.init(params)
    B, S = 8, 32
    shape = InputShape("t", S, B, "train")
    step = make_train_step(model, cfg, opt)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    out = {}
    for name, mesh in [("debug22", make_debug_mesh(2, 2)),
                       ("debug11", make_debug_mesh(1, 1))]:
        with mesh:
            in_sh, out_sh = train_shardings(params, st, cfg, mesh, shape)
            hlo = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(
                params, st, batch).compile().as_text()
        costs = analyze(hlo)
        pred = predict_train_collective_bytes(cfg, shape, mesh, params)
        out[name] = {"measured": float(costs.coll_total),
                     "predicted": float(pred["total"]),
                     "breakdown": {k: float(v) for k, v in costs.coll.items()}}
    print("RESULT", json.dumps(out))
""")


def test_sharded_step_collective_bytes_match_roofline_model():
    """ROADMAP item: measure the sharded step's collective bytes against the
    roofline model.  The prediction is a no-CSE all-reduce upper bound, so
    the measurement must land in [predicted/4, 1.5x predicted] on the (2,2)
    debug mesh; the (1,1) mesh must predict and measure exactly zero."""
    proc = subprocess.run([sys.executable, "-c", ROOFLINE_SCRIPT],
                          env=_ENV_BASE, capture_output=True, text=True,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    data = json.loads(line.split("RESULT ")[1])

    sharded = data["debug22"]
    assert sharded["predicted"] > 0
    ratio = sharded["measured"] / sharded["predicted"]
    assert 0.25 <= ratio <= 1.5, data
    # FSDP gathers + the data-axis gradient psum must both be present
    assert sharded["breakdown"].get("all-gather", 0) > 0, data
    assert sharded["breakdown"].get("all-reduce", 0) \
        + sharded["breakdown"].get("reduce-scatter", 0) > 0, data

    degenerate = data["debug11"]
    assert degenerate["predicted"] == 0
    assert degenerate["measured"] == 0, data


def test_train_cli_smoke():
    """The production entrypoint itself (module __main__, not a helper) runs
    3 steps green on the debug mesh — fast tier, no --runslow."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--steps", "3",
         "--mesh", "debug", "--nodes", "2", "--batch", "4", "--seq", "32"],
        env=_ENV_BASE, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "final loss" in proc.stdout
    assert "mesh=debug(2, 2)" in proc.stdout     # 8 forced devices -> (2,2)


# ---------------------------------------------------------------- in-process


def _sim_shards(sizes, seed=5):
    from repro.core.baselines import ShardData
    from repro.configs.paper_models import DATRET
    r = np.random.default_rng(seed)
    return [ShardData(
        r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
        r.integers(0, DATRET.n_classes, n)) for n in sizes]


@pytest.mark.parametrize("pipeline", [False, True],
                         ids=["sim-serial", "sim-pipelined"])
def test_engine_sim_facade_matches_orchestrator(pipeline):
    """mode="sim" is a faithful facade: same params as driving the
    TLOrchestrator directly with the matching pipelined flag."""
    import jax
    from repro.configs.paper_models import DATRET
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.plan import PlanSpec
    from repro.core.transport import Transport
    from repro.launch.engine import Engine
    from repro.models.small import SmallModel
    from repro.optim import sgd

    shards = _sim_shards([20, 12])
    model = SmallModel(DATRET)

    eng = Engine(model, DATRET, sgd(0.05), mode="sim", pipeline=pipeline,
                 batch_size=16, seed=0)
    res = eng.run(shards, epochs=2)

    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=16, plan=PlanSpec(seed=0),
                          pipelined=pipeline)
    orch.initialize(jax.random.PRNGKey(0))
    ref = [s for _ in range(2) for s in orch.train_epoch()]

    for pa, pb in zip(jax.tree.leaves(res.params), jax.tree.leaves(orch.params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert res.steps == len(ref)
    np.testing.assert_allclose(res.losses, [s.loss for s in ref], rtol=1e-6)
    assert len(res.epoch_stats) == 2


def test_engine_prefetch_is_double_buffered():
    """The producer thread fills the prefetch queue up to PREFETCH_DEPTH
    ahead of the consumer (and never further), preserves order, and runs
    off the consumer's thread.  Only scheduling-independent invariants are
    asserted — the slot semaphore upper-bounds the lookahead, it does not
    pin an exact interleaving."""
    import threading
    import time

    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import sgd

    cfg = get_config("deepseek-7b", reduced=True)
    eng = Engine(object(), cfg, sgd(0.1), make_debug_mesh(1, 1),
                 InputShape("t", 8, 4, "train"))
    events = []        # (kind, item, thread_ident); appends are GIL-atomic
    eng._put_batch = lambda hb: (
        events.append(("put", hb, threading.get_ident())), hb)[1]

    gen = eng._device_batches(iter(range(6)))
    first = next(gen)
    assert first == 0
    # with the consumer idle, the producer must fill the whole double
    # buffer on its own: item 0 is held by the consumer (slot unreleased),
    # item 1 materializes behind it — and nothing beyond PREFETCH_DEPTH
    deadline = time.monotonic() + 10.0
    while sum(e[0] == "put" for e in events) < 2:
        assert time.monotonic() < deadline, events
        time.sleep(0.001)
    time.sleep(0.05)   # give an (incorrect) over-eager producer rope
    puts_before_consume = [e[1] for e in events if e[0] == "put"]
    assert puts_before_consume == list(range(Engine.PREFETCH_DEPTH))

    events.append(("yield", first, threading.get_ident()))
    seen = [first]
    for item in gen:
        events.append(("yield", item, threading.get_ident()))
        seen.append(item)
    assert seen == list(range(6))

    # puts happen on the producer thread, not the consumer's
    consumer = threading.get_ident()
    assert all(t != consumer for k, _, t in events if k == "put")
    # at every prefix, materialized-ahead batches never exceed the depth:
    # put k+PREFETCH_DEPTH is gated on the consumer finishing item k
    outstanding = 0
    for kind, _, _ in events:
        outstanding += 1 if kind == "put" else -1
        assert outstanding <= Engine.PREFETCH_DEPTH, events
