"""Paper §4.3: inference-consistency validation.

The paper controls RNG (fixed seeds, no dropout/augmentation) and shows TL
and CL produce identical inference across repeated runs.  We assert the
stronger, testable forms:

* determinism — identical seeds give bit-identical parameters and logits
  for both the TL protocol and CL training;
* TL-vs-CL — training on the same virtual-batch sequence yields parameters
  whose *inference decisions* agree (losslessness carried to inference);
* repeated runs — 3 TL runs with the same seed produce identical metrics
  (the paper's "iterative training" check, 20 runs there, 3 here for CPU).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.paper_models import DATRET
from repro.core.node import TLNode, ce_sum
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.data.datasets import shard_iid, tabular
from repro.models import build_model
from repro.models.small import SmallModel
from repro.optim import sgd


def _run_tl(seed_data, seed_model, epochs=2):
    ds = tabular(300, 32, 4, seed=seed_data, margin=2.0, noise=0.8)
    train, test = ds.split(0.8, seed=0)
    shards = shard_iid(train, 4, seed=0)
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=30, plan=PlanSpec(seed=0),
                          check_consistency=False)
    orch.initialize(jax.random.PRNGKey(seed_model))
    for _ in range(epochs):
        orch.train_epoch()
    logits = model.forward(orch.params, jnp.asarray(test.x))
    return orch.params, np.asarray(logits)


def test_tl_runs_are_bit_deterministic():
    p1, l1 = _run_tl(0, 0)
    p2, l2 = _run_tl(0, 0)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(l1, l2)


def test_repeated_runs_identical_metrics():
    accs = []
    for _ in range(3):
        _, logits = _run_tl(0, 0)
        accs.append(logits.argmax(-1))
    assert np.array_equal(accs[0], accs[1]) and np.array_equal(accs[1],
                                                               accs[2])


def test_tl_cl_inference_decisions_agree():
    """TL trained on the exact virtual-batch sequence == CL on that
    sequence: inference decisions must agree everywhere."""
    ds = tabular(240, 32, 4, seed=3, margin=2.0, noise=0.8)
    train, test = ds.split(0.8, seed=0)
    shards = shard_iid(train, 4, seed=0)
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=24, plan=PlanSpec(seed=0),
                          check_consistency=False)
    key = jax.random.PRNGKey(7)
    orch.initialize(key)

    # CL twin: identical init, identical virtual batches
    p_cl = model.init(key)
    st = sgd(0.05).init(p_cl)
    xs = np.concatenate([np.asarray(n.x) for n in nodes])
    ys = np.concatenate([np.asarray(n.y) for n in nodes])
    sizes = [len(n.x) for n in nodes]
    offs = np.cumsum([0] + sizes[:-1])
    opt = sgd(0.05)
    for epoch in range(2):
        plan = orch.build_plan(epoch)
        for vb in plan.batches:
            rows = offs[plan.global_to_node[vb.global_ids]] \
                + plan.global_to_local[vb.global_ids]
            xb, yb = jnp.asarray(xs[rows]), jnp.asarray(ys[rows])
            g = jax.grad(lambda p: ce_sum(model.forward(p, xb), yb)
                         / vb.size)(p_cl)
            p_cl, st = opt.update(p_cl, g, st)
        orch.train_epoch()

    pred_tl = np.asarray(model.forward(orch.params, jnp.asarray(test.x))).argmax(-1)
    pred_cl = np.asarray(model.forward(p_cl, jnp.asarray(test.x))).argmax(-1)
    assert (pred_tl == pred_cl).mean() == 1.0


def test_production_model_init_deterministic():
    cfg = get_config("deepseek-7b", reduced=True)
    m = build_model(cfg)
    p1 = m.init(jax.random.PRNGKey(5))
    p2 = m.init(jax.random.PRNGKey(5))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
