"""Baseline DL methods: they train, and the paper's quality ordering holds
on a non-IID task (TL == CL > FedAvg with local epochs).
"""
import jax
import numpy as np
import pytest

import dataclasses

from repro.configs.paper_models import DATRET
from repro.core import baselines as B
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.data.datasets import shard_noniid, tabular
from repro.models.small import SmallModel
from repro.optim import sgd


@pytest.fixture(scope="module")
def task():
    ds = tabular(n=600, d=32, n_classes=4, seed=0, margin=2.0, noise=0.8)
    train, test = ds.split(0.8, seed=1)
    shards = shard_noniid(train, n_nodes=4, alpha=0.25, seed=2)
    sdata = [B.ShardData(jax.numpy.asarray(s.x), jax.numpy.asarray(s.y))
             for s in shards]
    return sdata, test


def test_cl_trains(task):
    sdata, test = task
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    p = B.train_cl(model, sdata, sgd(0.05), key=jax.random.PRNGKey(0),
                   epochs=3, batch_size=32)
    m = B.evaluate(model, p, test.x, test.y)
    assert m["acc"] > 0.5


def test_fl_trains_but_below_cl(task):
    sdata, test = task
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    key = jax.random.PRNGKey(0)
    p_cl = B.train_cl(model, sdata, sgd(0.05), key=key, epochs=3,
                      batch_size=32)
    tr = Transport()
    p_fl = B.train_fl(model, sdata, sgd(0.05), key=key, rounds=3,
                      local_epochs=1, batch_size=32, transport=tr)
    acc_cl = B.evaluate(model, p_cl, test.x, test.y)["acc"]
    acc_fl = B.evaluate(model, p_fl, test.x, test.y)["acc"]
    assert acc_fl > 0.3                       # it does learn
    assert acc_fl <= acc_cl + 0.05            # but does not beat CL
    assert tr.bytes_sent["model"] > 0         # model moved each round


def test_sl_and_sfl_train(task):
    sdata, test = task
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    key = jax.random.PRNGKey(0)
    p_sl = B.train_sl(model, sdata, sgd(0.05), key=key, rounds=2,
                      batch_size=32)
    p_slp = B.train_sl(model, sdata, sgd(0.05), key=key, rounds=2,
                       batch_size=32, no_label_sharing=True)
    p_sfl = B.train_sfl(model, sdata, sgd(0.05), key=key, rounds=2,
                        batch_size=32)
    for p in (p_sl, p_slp, p_sfl):
        assert B.evaluate(model, p, test.x, test.y)["acc"] > 0.3


def test_tl_matches_cl_on_noniid(task):
    """The paper's headline: TL == CL quality on non-IID shards."""
    sdata, test = task
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    key = jax.random.PRNGKey(0)
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(sdata)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=32, plan=PlanSpec(seed=0),
                          check_consistency=False)
    orch.initialize(key)
    for _ in range(3):
        orch.train_epoch()
    acc_tl = B.evaluate(model, orch.params, test.x, test.y)["acc"]
    p_cl = B.train_cl(model, sdata, sgd(0.05), key=key, epochs=3,
                      batch_size=32)
    acc_cl = B.evaluate(model, p_cl, test.x, test.y)["acc"]
    # same-quality claim: TL within noise of CL (they see the same data but
    # different shuffles)
    assert abs(acc_tl - acc_cl) < 0.1, (acc_tl, acc_cl)


def test_metrics_auc_and_f1():
    y = np.array([0, 0, 1, 1])
    import jax.numpy as jnp

    class Dummy:
        def forward(self, p, x):
            return jnp.asarray([[2.0, 0.0], [1.5, 0.2], [0.0, 2.0],
                                [0.1, 1.0]])
    m = B.evaluate(Dummy(), None, np.zeros((4, 1)), y)
    assert m["acc"] == 1.0 and m["auc"] == 1.0 and m["macro_f1"] == 1.0
