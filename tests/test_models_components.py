"""Model-component unit tests: attention paths, MoE invariants, analytics."""
import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.analysis.hlo_flops import _shape_elems_bytes
from repro.analysis.roofline import collective_bytes, shape_bytes
from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models.attention import attend_blockwise, attend_dense
from repro.models.layers import apply_mrope, apply_rope, causal_conv1d, \
    causal_conv1d_init, causal_conv1d_step, rmsnorm, rmsnorm_init


def test_blockwise_attention_equals_dense():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 2, 300, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, 2, D))
    v = jax.random.normal(ks[2], (B, S, 2, D))
    pos = jnp.arange(S)
    d = attend_dense(q, k, v, pos, pos, 0, 1 / math.sqrt(D))
    b = attend_blockwise(q, k, v, pos, pos, 0, 1 / math.sqrt(D), block=64)
    np.testing.assert_allclose(np.asarray(d), np.asarray(b), atol=1e-5)


def test_blockwise_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, D, W = 1, 200, 2, 16, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S)
    d = attend_dense(q, k, v, pos, pos, W, 1 / math.sqrt(D))
    b = attend_blockwise(q, k, v, pos, pos, W, 1 / math.sqrt(D), block=64)
    np.testing.assert_allclose(np.asarray(d), np.asarray(b), atol=1e-5)


def test_rope_relative_property():
    """RoPE: scores depend only on relative distance."""
    k = jax.random.PRNGKey(2)
    q = jax.random.normal(k, (1, 1, 1, 32))
    pos_a = jnp.asarray([[5]])
    pos_b = jnp.asarray([[9]])
    qa = apply_rope(q, pos_a)
    qb = apply_rope(q, pos_b)
    ka = apply_rope(q, pos_a + 3)
    kb = apply_rope(q, pos_b + 3)
    s_a = float(jnp.sum(qa * ka))
    s_b = float(jnp.sum(qb * kb))
    assert abs(s_a - s_b) < 1e-4


def test_mrope_text_only_equals_rope():
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (2, 6, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    p3 = jnp.broadcast_to(jnp.arange(6), (3, 2, 6))
    np.testing.assert_allclose(np.asarray(apply_rope(x, pos)),
                               np.asarray(apply_mrope(x, p3)), atol=1e-5)


def test_causal_conv_step_matches_full():
    k = jax.random.PRNGKey(4)
    p = causal_conv1d_init(k, channels=8, kernel=4)
    x = jax.random.normal(k, (2, 10, 8))
    full = causal_conv1d(p, x)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(10):
        state, o = causal_conv1d_step(p, state, x[:, t])
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-5)


def test_rmsnorm_scale_invariance():
    p = rmsnorm_init(16)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 16))
    y1 = rmsnorm(p, x)
    y2 = rmsnorm(p, x * 7.3)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


# ------------------------------------------------------------------- MoE

def _moe_cfg():
    return get_config("deepseek-v2-236b", reduced=True)


def test_moe_capacity_and_combine_weights():
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(6)
    p = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model)) * 0.1
    out, aux = moe_mod.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0


def test_moe_permutation_equivariance_within_group():
    """Dropless routing: permuting tokens in a group permutes outputs."""
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(7)
    p = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(key, (1, 8, cfg.d_model)) * 0.1
    out, _ = moe_mod.moe_apply(p, cfg, x)
    perm = jnp.asarray([3, 1, 7, 0, 2, 6, 4, 5])
    out_p, _ = moe_mod.moe_apply(p, cfg, x[:, perm])
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p),
                               atol=1e-4)


def test_moe_grads_flow_to_experts():
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(8)
    p = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model)) * 0.1

    def loss(pp):
        out, aux = moe_mod.moe_apply(pp, cfg, x)
        return jnp.sum(out ** 2) + aux
    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["w_gate"]).max()) > 0
    assert float(jnp.abs(g["router"]).max()) > 0


# --------------------------------------------------------------- analytics

def test_shape_bytes_parser():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("(bf16[8,4], s32[])") == 8 * 4 * 2 + 4
    assert shape_bytes("f32[]") == 4


def test_collective_regex_counts_types():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[32]{0} all-reduce(%y), to_apply=%add
  %rs = f32[16,8]{1,0} reduce-scatter(%z), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 128 * 4
    assert out["all-reduce"] == 32 * 2 * 2
    assert out["reduce-scatter"] == 16 * 8 * 4


@given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(max_examples=20, deadline=None)
def test_shape_elems_bytes_property(dims):
    s = "f32[" + ",".join(map(str, dims)) + "]"
    elems, nbytes = _shape_elems_bytes(s)
    expect = int(np.prod(dims)) if dims else 1
    assert elems == expect and nbytes == 4 * expect


def test_param_count_analytics_match_actual():
    """ModelConfig.n_params() tracks the real initialized tree within 10%."""
    import jax
    from repro.models import build_model
    for arch in ["deepseek-7b", "mamba2-780m"]:
        cfg = get_config(arch, reduced=True)
        m = build_model(cfg)
        params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        est = cfg.n_params()
        assert 0.6 < est / actual < 1.6, (arch, est, actual)
