"""Serving engine correctness: allocator properties, kernel equivalence,
continuous-batching oracle identity, sampling determinism.

The load-bearing guarantees (ISSUE 7 acceptance criteria):

* :class:`repro.serve.PageAllocator` never double-allocates or leaks pages
  across any alloc/free interleaving (hypothesis property tests);
* the Pallas paged-attention decode kernel matches dense attention to
  f32-ULP tolerance over a grid of shapes / shuffled block tables / ragged
  lengths (GQA and MLA fused-pool modes);
* continuous-batched greedy decoding is **token-identical** to the
  per-sequence static-batch oracle (``repro.launch.serve.generate``) across
  staggered admission/eviction schedules, ragged prompts, mid-stream EOS,
  single-token sequences, and both attention paths;
* seeded ``temperature>0`` streams depend only on (base key, request seed,
  step) — never on co-batched traffic — and equal the oracle's streams.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.launch.serve as launch_serve
from repro.configs import get_config
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_ref)
from repro.models import build_model
from repro.serve import (OutOfPages, PageAllocator, Request, ServeEngine,
                         TRASH_PAGE, check_servable)

PAGE = 4          # one page size across tests -> shared decode-fn compiles
POOL = 32

_SETUPS: dict = {}    # plain cache: @given-wrapped tests can't take fixtures


def _get_setup(arch):
    if arch not in _SETUPS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        _SETUPS[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _SETUPS[arch]


@pytest.fixture(scope="module")
def dense_setup():
    return _get_setup("deepseek-7b")


@pytest.fixture(scope="module")
def mla_setup():
    return _get_setup("deepseek-v2-236b")


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(p,)).astype(np.int32)
            for p in lens]


def _oracle(model, cfg, params, prompt, gen, temperature=0.0, seed=0):
    toks = launch_serve.generate(
        model, cfg, params, jnp.asarray(prompt)[None], gen,
        temperature=temperature, key=jax.random.PRNGKey(0), seeds=[seed])
    return [int(t) for t in np.asarray(toks)[0]]


def _engine(cfg, model, params, **kw):
    kw.setdefault("num_pages", POOL)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 32)
    return ServeEngine(model, cfg, params, **kw)


# ===================================================== allocator properties

class TestPageAllocator:
    def test_trash_page_never_handed_out(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(7)                    # the whole allocatable pool
        assert TRASH_PAGE not in pages
        assert sorted(pages) == list(range(1, 8))
        with pytest.raises(OutOfPages):
            alloc.alloc(1)

    def test_double_free_raises(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(2)
        alloc.free(pages)
        with pytest.raises(KeyError):
            alloc.free(pages)

    def test_refcounted_sharing(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(3)
        alloc.share(pages)                        # refcount 2
        alloc.free(pages)                         # still live
        assert alloc.live_pages == 3 and alloc.free_pages == 4
        alloc.free(pages)                         # refcount 0 -> returned
        assert alloc.live_pages == 0 and alloc.free_pages == 7

    @given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 5)),
                        min_size=1, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_alloc_free_exactly_once_and_conserved(self, ops):
        """Any alloc/free interleaving: no page is ever handed out twice
        concurrently, block tables stay disjoint, and the free list is
        conserved (free + live == capacity) after every operation."""
        alloc = PageAllocator(16, PAGE)
        tables = []                               # outstanding allocations
        for is_alloc, n in ops:
            if is_alloc:
                try:
                    pages = alloc.alloc(n)
                except OutOfPages:
                    assert alloc.free_pages < n
                    continue
                live = {p for t in tables for p in t}
                assert len(set(pages)) == len(pages)
                assert not set(pages) & live      # disjoint block tables
                assert TRASH_PAGE not in pages
                tables.append(pages)
            elif tables:
                alloc.free(tables.pop(n % len(tables)))
            assert alloc.free_pages + alloc.live_pages == alloc.num_pages - 1
            assert alloc.live_pages == len({p for t in tables for p in t})
        for t in tables:
            alloc.free(t)
        assert alloc.free_pages == alloc.num_pages - 1
        assert alloc.live_pages == 0


# ============================================ paged kernel vs dense oracle

KERNEL_GRID = [
    # B, H, KV, d,  page, maxp
    (3, 4, 2, 16, 4, 4),          # GQA
    (2, 8, 8, 32, 8, 2),          # MHA
    (1, 4, 1, 64, 4, 3),          # MQA
    (4, 4, 4, 16, 4, 5),          # bigger batch
]


@pytest.mark.parametrize("B,H,KV,d,page,maxp", KERNEL_GRID)
def test_paged_kernel_matches_dense_ref(B, H, KV, d, page, maxp):
    rng = np.random.default_rng(B * 100 + H)
    P = B * maxp + 1
    kp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    # shuffled, non-contiguous block tables (page 0 kept as trash)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * maxp]
                     .reshape(B, maxp), jnp.int32)
    # ragged lengths: 1, a page boundary, full, and something in between
    lens = np.ones((B,), np.int32)
    lens[1 % B] = page                            # exact page boundary
    lens[(2 % B)] = maxp * page                   # completely full
    if B > 3:
        lens[3] = page + 1
    lens = jnp.asarray(lens)
    out = paged_decode_attention(q, kp, vp, bt, lens, scale=d ** -0.5)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_paged_kernel_mla_fused_pool():
    """MLA mode: one fused c_kv‖k_rope pool, values = latent prefix."""
    rng = np.random.default_rng(7)
    B, H, lora, rope, page, maxp = 3, 4, 32, 16, 4, 4
    d = lora + rope
    P = B * maxp + 1
    kp = jnp.asarray(rng.normal(size=(P, page, 1, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * maxp]
                     .reshape(B, maxp), jnp.int32)
    lens = jnp.asarray([1, page, maxp * page], jnp.int32)
    out = paged_decode_attention(q, kp, None, bt, lens, scale=d ** -0.5,
                                 v_width=lora)
    ref = paged_decode_attention_ref(q, kp, None, bt, lens, scale=d ** -0.5,
                                     v_width=lora)
    assert out.shape == (B, H, lora)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_trash_page_contents_cannot_leak():
    """Garbage in page 0 (inactive-slot writes land there) must never move
    a live sequence's output: masked positions contribute exactly zero."""
    rng = np.random.default_rng(9)
    B, H, KV, d, page, maxp = 2, 4, 2, 16, 4, 3
    P = B * maxp + 1
    kp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    bt = np.arange(1, 1 + B * maxp, dtype=np.int32).reshape(B, maxp)
    bt[:, -1] = TRASH_PAGE                        # tail slots -> trash
    lens = jnp.asarray([3, 2 * page], jnp.int32)  # never reach the tail page
    base = paged_decode_attention(q, kp, vp, jnp.asarray(bt), lens,
                                  scale=d ** -0.5)
    kp2 = kp.at[TRASH_PAGE].set(1e6)              # poison the trash page
    vp2 = vp.at[TRASH_PAGE].set(-1e6)
    poisoned = paged_decode_attention(q, kp2, vp2, jnp.asarray(bt), lens,
                                      scale=d ** -0.5)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))


# ================================== continuous batching == static oracle

SCHEDULES = {
    "all_at_once": [0, 0, 0, 0],
    "staggered": [0, 2, 3, 9],
    "serialized": [0, 40, 80, 120],
}


@pytest.mark.parametrize("attention", ["dense", "paged"])
@pytest.mark.parametrize("schedule", sorted(SCHEDULES))
def test_engine_greedy_token_identical(dense_setup, attention, schedule):
    """Ragged prompts (incl. single-token) under every admission schedule:
    the engine's greedy streams equal per-sequence static-batch decoding."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 1, 9, 3])
    gens = [6, 4, 8, 3]
    eng = _engine(cfg, model, params, attention=attention)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gens[i])
            for i in range(4)]
    res = eng.serve(reqs, arrival_steps=SCHEDULES[schedule])
    for i in range(4):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), (attention, schedule, i)
        assert res[i].finish_reason == "length"
    # no leaks: every page freed, every reservation released
    assert eng.alloc.live_pages == 0
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1
    assert eng._reserved == 0


def test_engine_mla_arch_token_identical(mla_setup):
    """The MLA+MoE arch (fused latent pool, v_width kernel mode) through
    the full engine, staggered."""
    cfg, model, params = mla_setup
    prompts = _prompts(cfg, [5, 3, 8])
    gens = [5, 6, 4]
    eng = _engine(cfg, model, params, attention="paged")
    res = eng.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=gens[i])
                     for i in range(3)], arrival_steps=[0, 1, 4])
    for i in range(3):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), i


def test_engine_mid_stream_eos(dense_setup):
    """EOS mid-stream evicts the sequence and frees its pages; the emitted
    stream is the oracle's, truncated inclusively at the EOS token."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    full = _oracle(model, cfg, params, prompt, 8)
    eos = full[2]                        # a token the greedy stream emits
    cut = full.index(eos) + 1            # engine stops at first occurrence
    assert cut < len(full)
    eng = _engine(cfg, model, params)
    res = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=8,
                             eos_id=eos)])
    assert res[0].tokens == full[:cut]
    assert res[0].finish_reason == "eos"
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_engine_single_token_sequences(dense_setup):
    """max_new_tokens=1 finishes straight out of prefill (never enters the
    decode batch), co-scheduled with longer traffic."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [4, 6, 2])
    eng = _engine(cfg, model, params)
    res = eng.serve([
        Request(rid=0, prompt=prompts[0], max_new_tokens=1),
        Request(rid=1, prompt=prompts[1], max_new_tokens=5),
        Request(rid=2, prompt=prompts[2], max_new_tokens=1),
    ], arrival_steps=[0, 0, 2])
    assert res[0].tokens == _oracle(model, cfg, params, prompts[0], 1)
    assert res[1].tokens == _oracle(model, cfg, params, prompts[1], 5)
    assert res[2].tokens == _oracle(model, cfg, params, prompts[2], 1)
    assert res[0].finish_reason == "length" and len(res[0].tokens) == 1


def test_engine_capacity_backpressure(dense_setup):
    """A pool that fits ~one sequence serializes admissions (head-of-line
    waits for eviction) without corrupting any stream."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 7, 3])
    gens = [6, 4, 5]
    # pages_for(max P+gen)=pages_for(11)=3 -> pool of 4 allocatable fits one
    # sequence plus slack but never two
    eng = _engine(cfg, model, params, num_pages=5, max_len=12)
    res = eng.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=gens[i])
                     for i in range(3)])
    for i in range(3):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), i
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_engine_rejects_impossible_requests(dense_setup):
    cfg, model, params = dense_setup
    eng = _engine(cfg, model, params, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros((10,), np.int32),
                           max_new_tokens=8))     # 18 > max_len
    with pytest.raises(ValueError):
        eng.submit(Request(rid=1, prompt=np.zeros((0,), np.int32),
                           max_new_tokens=2))


@given(plens=st.lists(st.integers(1, 9), min_size=1, max_size=4),
       arrivals=st.lists(st.integers(0, 12), min_size=4, max_size=4),
       gens=st.lists(st.integers(1, 6), min_size=4, max_size=4))
@settings(max_examples=5, deadline=None)
def test_engine_random_schedules_property(plens, arrivals, gens):
    """Hypothesis-driven admit/evict schedules: token identity + page
    conservation hold for arbitrary ragged traffic."""
    cfg, model, params = _get_setup("deepseek-7b")
    prompts = _prompts(cfg, plens, seed=sum(plens))
    n = len(prompts)
    eng = _engine(cfg, model, params)
    res = eng.serve([Request(rid=i, prompt=prompts[i],
                             max_new_tokens=gens[i]) for i in range(n)],
                    arrival_steps=arrivals[:n])
    for i in range(n):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), i
    assert eng.alloc.live_pages == 0
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1
    assert eng._reserved == 0


# ======================================== sampling determinism (temp > 0)

def test_sampled_stream_independent_of_cobatch(dense_setup):
    """A seeded temperature>0 request emits the same stream alone and
    co-batched with unrelated traffic (per-request RNG streams)."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 9, 3])
    solo = _engine(cfg, model, params)
    a = solo.serve([Request(rid=0, prompt=prompts[0], max_new_tokens=6,
                            temperature=0.8, seed=7)])[0].tokens
    crowd = _engine(cfg, model, params)
    b = crowd.serve([
        Request(rid=0, prompt=prompts[0], max_new_tokens=6,
                temperature=0.8, seed=7),
        Request(rid=1, prompt=prompts[1], max_new_tokens=8,
                temperature=0.9, seed=11),
        Request(rid=2, prompt=prompts[2], max_new_tokens=4,
                temperature=0.0, seed=13),
    ], arrival_steps=[0, 0, 1])[0].tokens
    assert a == b
    assert len(a) == 6


def test_sampled_stream_matches_oracle(dense_setup):
    """Engine seeded stream == static-batch oracle seeded stream (same
    base key, same request seed, same fold_in(step) positions)."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    eng = _engine(cfg, model, params, seed=0)
    got = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=6,
                             temperature=0.8, seed=7)])[0].tokens
    assert got == _oracle(model, cfg, params, prompt, 6, temperature=0.8,
                          seed=7)


def test_generate_survives_temperature_without_key(dense_setup):
    """Seed-era bug: ``generate(..., temperature>0, key=None)`` crashed on
    ``jax.random.split(None)``.  It must sample with the default key now."""
    cfg, model, params = dense_setup
    prompts = jnp.asarray(_prompts(cfg, [4, 4]))
    toks = launch_serve.generate(model, cfg, params, prompts, 3,
                                 temperature=0.7)
    assert toks.shape == (2, 3)


def test_generate_does_not_rejit_per_call(dense_setup, monkeypatch):
    """Seed-era bug: the jitted serve step was rebuilt inside ``generate``
    on every call.  It must come from the per-config cache."""
    cfg, model, params = dense_setup
    calls = []
    orig = launch_serve.make_serve_step

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(launch_serve, "make_serve_step", counting)
    launch_serve._STEP_CACHE.pop(cfg.name, None)
    prompts = jnp.asarray(_prompts(cfg, [4, 4]))
    launch_serve.generate(model, cfg, params, prompts, 2)
    launch_serve.generate(model, cfg, params, prompts, 2)
    launch_serve.generate(model, cfg, params, prompts, 3)
    assert len(calls) == 1


# =========================================================== servable gate

@pytest.mark.parametrize("arch,reason", [
    ("starcoder2-3b", "attention"),       # sliding-window ring cache
    ("mamba2-780m", "mixer"),             # ssm mixer
    ("qwen2-vl-72b", "mrope"),            # mrope positions
    ("seamless-m4t-medium", "encoder"),   # enc-dec
])
def test_unservable_archs_raise(arch, reason):
    cfg = get_config(arch, reduced=True)
    with pytest.raises(ValueError, match="not servable"):
        check_servable(cfg)
    with pytest.raises(ValueError, match=reason):
        check_servable(cfg)


def test_servable_archs_pass():
    for arch in ("deepseek-7b", "deepseek-v2-236b", "qwen2.5-32b"):
        check_servable(get_config(arch, reduced=True))


# ================================================================ CLI shim

def test_cli_continuous_smoke(capsys):
    res = launch_serve.main([
        "--arch", "deepseek-7b", "--engine", "continuous",
        "--attention", "paged", "--batch", "2", "--prompt-len", "4",
        "--gen", "3", "--page-size", "4", "--num-pages", "32"])
    assert len(res) == 2
    assert all(len(r.tokens) == 3 for r in res.values())
    assert "served 2 requests" in capsys.readouterr().out


def test_cli_static_smoke(capsys):
    toks = launch_serve.main([
        "--arch", "deepseek-7b", "--batch", "2", "--prompt-len", "4",
        "--gen", "3"])
    assert toks.shape == (2, 3)
