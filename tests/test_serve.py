"""Serving engine correctness: allocator properties, kernel equivalence,
continuous-batching oracle identity, sampling determinism.

The load-bearing guarantees (ISSUE 7 acceptance criteria):

* :class:`repro.serve.PageAllocator` never double-allocates or leaks pages
  across any alloc/free interleaving (hypothesis property tests);
* the Pallas paged-attention decode kernel matches dense attention to
  f32-ULP tolerance over a grid of shapes / shuffled block tables / ragged
  lengths (GQA and MLA fused-pool modes);
* continuous-batched greedy decoding is **token-identical** to the
  per-sequence static-batch oracle (``repro.launch.serve.generate``) across
  staggered admission/eviction schedules, ragged prompts, mid-stream EOS,
  single-token sequences, and both attention paths;
* seeded ``temperature>0`` streams depend only on (base key, request seed,
  step) — never on co-batched traffic — and equal the oracle's streams.

Serving under fire (ISSUE 9 acceptance criteria):

* KV preemption/restore is token-identical at page-boundary and
  ``max_new_tokens=1`` edges, for greedy and sampled streams, across
  arbitrary hypothesis-driven interleavings (with per-step allocator
  invariant checks);
* SLO deadlines shed queued requests and abort in-flight ones explicitly
  (never silently), and the aborted partial prefix is still the oracle's;
  head-of-line bypass is bounded; priorities preempt lower in-flight work;
* injected decode-step hangs (watchdog-classified) and crashes recover
  under supervision with streams bit-identical to the fault-free run, and
  fail loudly (with a state dump) without supervision.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.launch.serve as launch_serve
from repro.configs import get_config
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_ref)
from repro.models import build_model
from repro.serve import (CRASH, HANG, OutOfPages, PageAllocator, Request,
                         ServeDrill, ServeEngine, ServeFault,
                         ServeFaultInjector, ServeFaultSpec, TRASH_PAGE,
                         check_servable, parse_chaos)

PAGE = 4          # one page size across tests -> shared decode-fn compiles
POOL = 32

_SETUPS: dict = {}    # plain cache: @given-wrapped tests can't take fixtures


def _get_setup(arch):
    if arch not in _SETUPS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        _SETUPS[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _SETUPS[arch]


@pytest.fixture(scope="module")
def dense_setup():
    return _get_setup("deepseek-7b")


@pytest.fixture(scope="module")
def mla_setup():
    return _get_setup("deepseek-v2-236b")


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(p,)).astype(np.int32)
            for p in lens]


def _oracle(model, cfg, params, prompt, gen, temperature=0.0, seed=0):
    toks = launch_serve.generate(
        model, cfg, params, jnp.asarray(prompt)[None], gen,
        temperature=temperature, key=jax.random.PRNGKey(0), seeds=[seed])
    return [int(t) for t in np.asarray(toks)[0]]


def _engine(cfg, model, params, **kw):
    kw.setdefault("num_pages", POOL)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 32)
    return ServeEngine(model, cfg, params, **kw)


# ===================================================== allocator properties

class TestPageAllocator:
    def test_trash_page_never_handed_out(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(7)                    # the whole allocatable pool
        assert TRASH_PAGE not in pages
        assert sorted(pages) == list(range(1, 8))
        with pytest.raises(OutOfPages):
            alloc.alloc(1)

    def test_double_free_raises(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(2)
        alloc.free(pages)
        with pytest.raises(KeyError):
            alloc.free(pages)

    def test_free_is_atomic_on_partial_double_free(self):
        """A bad batch (one live page + one stale) must raise *before* any
        refcount moves — the live page stays allocated, nothing leaks."""
        alloc = PageAllocator(8, PAGE)
        live = alloc.alloc(2)
        stale = alloc.alloc(1)
        alloc.free(stale)
        with pytest.raises(KeyError):
            alloc.free(live[:1] + stale)
        assert alloc.live_pages == 2              # untouched by the bad call
        alloc.free(live)
        assert alloc.free_pages == 7 and alloc.live_pages == 0

    def test_free_counts_duplicates_within_one_call(self):
        """``free([p, p])`` of a singly-referenced page is a double free —
        it must raise, not push ``p`` onto the free list twice."""
        alloc = PageAllocator(8, PAGE)
        [p] = alloc.alloc(1)
        with pytest.raises(KeyError):
            alloc.free([p, p])
        assert alloc.live_pages == 1
        alloc.free([p])
        assert alloc.free_pages == 7

    def test_share_unknown_page_is_atomic(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(2)
        with pytest.raises(KeyError):
            alloc.share(pages + [7])              # 7 never allocated
        alloc.free(pages)                         # refcounts never bumped
        assert alloc.free_pages == 7 and alloc.live_pages == 0

    def test_refcounted_sharing(self):
        alloc = PageAllocator(8, PAGE)
        pages = alloc.alloc(3)
        alloc.share(pages)                        # refcount 2
        alloc.free(pages)                         # still live
        assert alloc.live_pages == 3 and alloc.free_pages == 4
        alloc.free(pages)                         # refcount 0 -> returned
        assert alloc.live_pages == 0 and alloc.free_pages == 7

    @given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 5)),
                        min_size=1, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_alloc_free_exactly_once_and_conserved(self, ops):
        """Any alloc/free interleaving: no page is ever handed out twice
        concurrently, block tables stay disjoint, and the free list is
        conserved (free + live == capacity) after every operation."""
        alloc = PageAllocator(16, PAGE)
        tables = []                               # outstanding allocations
        for is_alloc, n in ops:
            if is_alloc:
                try:
                    pages = alloc.alloc(n)
                except OutOfPages:
                    assert alloc.free_pages < n
                    continue
                live = {p for t in tables for p in t}
                assert len(set(pages)) == len(pages)
                assert not set(pages) & live      # disjoint block tables
                assert TRASH_PAGE not in pages
                tables.append(pages)
            elif tables:
                alloc.free(tables.pop(n % len(tables)))
            assert alloc.free_pages + alloc.live_pages == alloc.num_pages - 1
            assert alloc.live_pages == len({p for t in tables for p in t})
        for t in tables:
            alloc.free(t)
        assert alloc.free_pages == alloc.num_pages - 1
        assert alloc.live_pages == 0


# ============================================ paged kernel vs dense oracle

KERNEL_GRID = [
    # B, H, KV, d,  page, maxp
    (3, 4, 2, 16, 4, 4),          # GQA
    (2, 8, 8, 32, 8, 2),          # MHA
    (1, 4, 1, 64, 4, 3),          # MQA
    (4, 4, 4, 16, 4, 5),          # bigger batch
]


@pytest.mark.parametrize("B,H,KV,d,page,maxp", KERNEL_GRID)
def test_paged_kernel_matches_dense_ref(B, H, KV, d, page, maxp):
    rng = np.random.default_rng(B * 100 + H)
    P = B * maxp + 1
    kp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    # shuffled, non-contiguous block tables (page 0 kept as trash)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * maxp]
                     .reshape(B, maxp), jnp.int32)
    # ragged lengths: 1, a page boundary, full, and something in between
    lens = np.ones((B,), np.int32)
    lens[1 % B] = page                            # exact page boundary
    lens[(2 % B)] = maxp * page                   # completely full
    if B > 3:
        lens[3] = page + 1
    lens = jnp.asarray(lens)
    out = paged_decode_attention(q, kp, vp, bt, lens, scale=d ** -0.5)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_paged_kernel_mla_fused_pool():
    """MLA mode: one fused c_kv‖k_rope pool, values = latent prefix."""
    rng = np.random.default_rng(7)
    B, H, lora, rope, page, maxp = 3, 4, 32, 16, 4, 4
    d = lora + rope
    P = B * maxp + 1
    kp = jnp.asarray(rng.normal(size=(P, page, 1, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * maxp]
                     .reshape(B, maxp), jnp.int32)
    lens = jnp.asarray([1, page, maxp * page], jnp.int32)
    out = paged_decode_attention(q, kp, None, bt, lens, scale=d ** -0.5,
                                 v_width=lora)
    ref = paged_decode_attention_ref(q, kp, None, bt, lens, scale=d ** -0.5,
                                     v_width=lora)
    assert out.shape == (B, H, lora)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_trash_page_contents_cannot_leak():
    """Garbage in page 0 (inactive-slot writes land there) must never move
    a live sequence's output: masked positions contribute exactly zero."""
    rng = np.random.default_rng(9)
    B, H, KV, d, page, maxp = 2, 4, 2, 16, 4, 3
    P = B * maxp + 1
    kp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    bt = np.arange(1, 1 + B * maxp, dtype=np.int32).reshape(B, maxp)
    bt[:, -1] = TRASH_PAGE                        # tail slots -> trash
    lens = jnp.asarray([3, 2 * page], jnp.int32)  # never reach the tail page
    base = paged_decode_attention(q, kp, vp, jnp.asarray(bt), lens,
                                  scale=d ** -0.5)
    kp2 = kp.at[TRASH_PAGE].set(1e6)              # poison the trash page
    vp2 = vp.at[TRASH_PAGE].set(-1e6)
    poisoned = paged_decode_attention(q, kp2, vp2, jnp.asarray(bt), lens,
                                      scale=d ** -0.5)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))


# ================================== continuous batching == static oracle

SCHEDULES = {
    "all_at_once": [0, 0, 0, 0],
    "staggered": [0, 2, 3, 9],
    "serialized": [0, 40, 80, 120],
}


@pytest.mark.parametrize("attention", ["dense", "paged"])
@pytest.mark.parametrize("schedule", sorted(SCHEDULES))
def test_engine_greedy_token_identical(dense_setup, attention, schedule):
    """Ragged prompts (incl. single-token) under every admission schedule:
    the engine's greedy streams equal per-sequence static-batch decoding."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 1, 9, 3])
    gens = [6, 4, 8, 3]
    eng = _engine(cfg, model, params, attention=attention)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gens[i])
            for i in range(4)]
    res = eng.serve(reqs, arrival_steps=SCHEDULES[schedule])
    for i in range(4):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), (attention, schedule, i)
        assert res[i].finish_reason == "length"
    # no leaks: every page freed, every reservation released
    assert eng.alloc.live_pages == 0
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1
    assert eng._reserved == 0


def test_engine_mla_arch_token_identical(mla_setup):
    """The MLA+MoE arch (fused latent pool, v_width kernel mode) through
    the full engine, staggered."""
    cfg, model, params = mla_setup
    prompts = _prompts(cfg, [5, 3, 8])
    gens = [5, 6, 4]
    eng = _engine(cfg, model, params, attention="paged")
    res = eng.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=gens[i])
                     for i in range(3)], arrival_steps=[0, 1, 4])
    for i in range(3):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), i


def test_engine_mid_stream_eos(dense_setup):
    """EOS mid-stream evicts the sequence and frees its pages; the emitted
    stream is the oracle's, truncated inclusively at the EOS token."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    full = _oracle(model, cfg, params, prompt, 8)
    eos = full[2]                        # a token the greedy stream emits
    cut = full.index(eos) + 1            # engine stops at first occurrence
    assert cut < len(full)
    eng = _engine(cfg, model, params)
    res = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=8,
                             eos_id=eos)])
    assert res[0].tokens == full[:cut]
    assert res[0].finish_reason == "eos"
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_engine_single_token_sequences(dense_setup):
    """max_new_tokens=1 finishes straight out of prefill (never enters the
    decode batch), co-scheduled with longer traffic."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [4, 6, 2])
    eng = _engine(cfg, model, params)
    res = eng.serve([
        Request(rid=0, prompt=prompts[0], max_new_tokens=1),
        Request(rid=1, prompt=prompts[1], max_new_tokens=5),
        Request(rid=2, prompt=prompts[2], max_new_tokens=1),
    ], arrival_steps=[0, 0, 2])
    assert res[0].tokens == _oracle(model, cfg, params, prompts[0], 1)
    assert res[1].tokens == _oracle(model, cfg, params, prompts[1], 5)
    assert res[2].tokens == _oracle(model, cfg, params, prompts[2], 1)
    assert res[0].finish_reason == "length" and len(res[0].tokens) == 1


def test_engine_capacity_backpressure(dense_setup):
    """A pool that fits ~one sequence serializes admissions (head-of-line
    waits for eviction) without corrupting any stream."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 7, 3])
    gens = [6, 4, 5]
    # pages_for(max P+gen)=pages_for(11)=3 -> pool of 4 allocatable fits one
    # sequence plus slack but never two
    eng = _engine(cfg, model, params, num_pages=5, max_len=12)
    res = eng.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=gens[i])
                     for i in range(3)])
    for i in range(3):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), i
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_engine_rejects_impossible_requests(dense_setup):
    cfg, model, params = dense_setup
    eng = _engine(cfg, model, params, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros((10,), np.int32),
                           max_new_tokens=8))     # 18 > max_len
    with pytest.raises(ValueError):
        eng.submit(Request(rid=1, prompt=np.zeros((0,), np.int32),
                           max_new_tokens=2))


@given(plens=st.lists(st.integers(1, 9), min_size=1, max_size=4),
       arrivals=st.lists(st.integers(0, 12), min_size=4, max_size=4),
       gens=st.lists(st.integers(1, 6), min_size=4, max_size=4))
@settings(max_examples=5, deadline=None)
def test_engine_random_schedules_property(plens, arrivals, gens):
    """Hypothesis-driven admit/evict schedules: token identity + page
    conservation hold for arbitrary ragged traffic."""
    cfg, model, params = _get_setup("deepseek-7b")
    prompts = _prompts(cfg, plens, seed=sum(plens))
    n = len(prompts)
    eng = _engine(cfg, model, params)
    res = eng.serve([Request(rid=i, prompt=prompts[i],
                             max_new_tokens=gens[i]) for i in range(n)],
                    arrival_steps=arrivals[:n])
    for i in range(n):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), i
    assert eng.alloc.live_pages == 0
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1
    assert eng._reserved == 0


# ======================================== sampling determinism (temp > 0)

def test_sampled_stream_independent_of_cobatch(dense_setup):
    """A seeded temperature>0 request emits the same stream alone and
    co-batched with unrelated traffic (per-request RNG streams)."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 9, 3])
    solo = _engine(cfg, model, params)
    a = solo.serve([Request(rid=0, prompt=prompts[0], max_new_tokens=6,
                            temperature=0.8, seed=7)])[0].tokens
    crowd = _engine(cfg, model, params)
    b = crowd.serve([
        Request(rid=0, prompt=prompts[0], max_new_tokens=6,
                temperature=0.8, seed=7),
        Request(rid=1, prompt=prompts[1], max_new_tokens=8,
                temperature=0.9, seed=11),
        Request(rid=2, prompt=prompts[2], max_new_tokens=4,
                temperature=0.0, seed=13),
    ], arrival_steps=[0, 0, 1])[0].tokens
    assert a == b
    assert len(a) == 6


def test_sampled_stream_matches_oracle(dense_setup):
    """Engine seeded stream == static-batch oracle seeded stream (same
    base key, same request seed, same fold_in(step) positions)."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    eng = _engine(cfg, model, params, seed=0)
    got = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=6,
                             temperature=0.8, seed=7)])[0].tokens
    assert got == _oracle(model, cfg, params, prompt, 6, temperature=0.8,
                          seed=7)


def test_generate_survives_temperature_without_key(dense_setup):
    """Seed-era bug: ``generate(..., temperature>0, key=None)`` crashed on
    ``jax.random.split(None)``.  It must sample with the default key now."""
    cfg, model, params = dense_setup
    prompts = jnp.asarray(_prompts(cfg, [4, 4]))
    toks = launch_serve.generate(model, cfg, params, prompts, 3,
                                 temperature=0.7)
    assert toks.shape == (2, 3)


def test_generate_does_not_rejit_per_call(dense_setup, monkeypatch):
    """Seed-era bug: the jitted serve step was rebuilt inside ``generate``
    on every call.  It must come from the per-config cache."""
    cfg, model, params = dense_setup
    calls = []
    orig = launch_serve.make_serve_step

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(launch_serve, "make_serve_step", counting)
    launch_serve._STEP_CACHE.pop(cfg.name, None)
    prompts = jnp.asarray(_prompts(cfg, [4, 4]))
    launch_serve.generate(model, cfg, params, prompts, 2)
    launch_serve.generate(model, cfg, params, prompts, 2)
    launch_serve.generate(model, cfg, params, prompts, 3)
    assert len(calls) == 1


# =========================================================== servable gate

@pytest.mark.parametrize("arch,reason", [
    ("starcoder2-3b", "attention"),       # sliding-window ring cache
    ("mamba2-780m", "mixer"),             # ssm mixer
    ("qwen2-vl-72b", "mrope"),            # mrope positions
    ("seamless-m4t-medium", "encoder"),   # enc-dec
])
def test_unservable_archs_raise(arch, reason):
    cfg = get_config(arch, reduced=True)
    with pytest.raises(ValueError, match="not servable"):
        check_servable(cfg)
    with pytest.raises(ValueError, match=reason):
        check_servable(cfg)


def test_servable_archs_pass():
    for arch in ("deepseek-7b", "deepseek-v2-236b", "qwen2.5-32b"):
        check_servable(get_config(arch, reduced=True))


# ==================== serving under fire (ISSUE 9): preempt/SLO/faults

class FakeClock:
    """Manually-advanced engine clock for deterministic SLO tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_submit_rejects_duplicate_rid(dense_setup):
    cfg, model, params = dense_setup
    eng = _engine(cfg, model, params)
    [p] = _prompts(cfg, [4])
    eng.submit(Request(rid=7, prompt=p, max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate rid"):
        eng.submit(Request(rid=7, prompt=p, max_new_tokens=2))


@pytest.mark.parametrize("attention", ["dense", "paged"])
@pytest.mark.parametrize("preempt_step", [1, 2, 3, 4])
def test_preempt_restore_token_identical(dense_setup, attention,
                                         preempt_step):
    """Forced KV eviction at every phase of a stream — right after the
    prefill token (re-prefill is the bare prompt), at an exact page
    boundary, and deep into decode — restores bit-identically: the
    re-prefilled prefix resumes the same RNG stream position."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 3])
    gens = [6, 7]
    eng = _engine(cfg, model, params, attention=attention)
    res = eng.serve([Request(rid=i, prompt=prompts[i],
                             max_new_tokens=gens[i]) for i in range(2)],
                    preempt_at=[(preempt_step, 0)])
    for i in range(2):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), (attention, i)
        assert res[i].finish_reason == "length"
    assert res[0].preemptions == 1 and res[1].preemptions == 0
    assert eng.n_preempted == 1 and eng.n_restored == 1
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_preempt_with_single_token_cobatch(dense_setup):
    """max_new_tokens=1 edge: a request that finishes straight out of
    prefill admits *while* another sequence sits evicted, and neither
    stream moves."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 2])
    eng = _engine(cfg, model, params)
    res = eng.serve([Request(rid=0, prompt=prompts[0], max_new_tokens=6),
                     Request(rid=1, prompt=prompts[1], max_new_tokens=1)],
                    arrival_steps=[0, 2], preempt_at=[(2, 0)])
    assert res[0].tokens == _oracle(model, cfg, params, prompts[0], 6)
    assert res[1].tokens == _oracle(model, cfg, params, prompts[1], 1)
    assert res[0].preemptions == 1


def test_preempt_restore_preserves_sampled_stream(dense_setup):
    """Seeded temperature>0 stream across an eviction == the solo oracle
    stream: RNG position folds in (seed, step), never cache history."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    eng = _engine(cfg, model, params, seed=0)
    res = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=6,
                             temperature=0.8, seed=7)],
                    preempt_at=[(3, 0)])
    assert res[0].preemptions == 1
    assert res[0].tokens == _oracle(model, cfg, params, prompt, 6,
                                    temperature=0.8, seed=7)


@given(arrivals=st.lists(st.integers(0, 8), min_size=3, max_size=3),
       preempts=st.lists(st.tuples(st.integers(1, 12), st.integers(0, 2)),
                         min_size=0, max_size=4))
@settings(max_examples=5, deadline=None)
def test_preempt_interleavings_conserve_pages_property(arrivals, preempts):
    """Hypothesis: arbitrary admit/preempt/restore/evict interleavings
    keep the free list conserved, never double-map a page, and stay
    token-identical.  ``check_invariants`` runs after every step."""
    cfg, model, params = _get_setup("deepseek-7b")
    prompts = _prompts(cfg, [5, 1, 7], seed=11)
    gens = [6, 3, 5]
    eng = _engine(cfg, model, params)
    order = sorted(range(3), key=lambda i: arrivals[i])
    i = 0
    while i < len(order) or not eng.idle:
        while i < len(order) and eng.n_steps >= arrivals[order[i]]:
            eng.submit(Request(rid=order[i], prompt=prompts[order[i]],
                               max_new_tokens=gens[order[i]]))
            i += 1
        if eng.idle and i < len(order):
            eng.n_steps = arrivals[order[i]]
            continue
        for st_, rid in preempts:
            if st_ == eng.n_steps:
                eng.preempt(rid)
        eng.step()
        eng.check_invariants()
    for r in range(3):
        assert eng.results[r].tokens == _oracle(model, cfg, params,
                                                prompts[r], gens[r]), r
    assert eng.alloc.live_pages == 0
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1


def test_overcommit_out_of_pages_preempts_victim(dense_setup):
    """Overcommit mode admits on prompt pages only, so lazy growth can hit
    ``OutOfPages`` mid-decode; the engine survives by evicting the
    youngest lowest-priority sequence, and every stream stays oracle."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 5])
    eng = _engine(cfg, model, params, num_pages=5, max_len=12,
                  overcommit=True)
    res = eng.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=6)
                     for i in range(2)])
    assert eng.n_preempted >= 1                   # growth ran out of pages
    for i in range(2):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i], 6), i
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


# --------------------------------------------------- SLO / overload control

def test_deadline_aborts_inflight_with_partial_prefix(dense_setup):
    """A sequence past its deadline is aborted mid-stream: pages freed,
    result flagged partial, and the partial tokens are exactly the oracle
    prefix (an abort never corrupts what was already emitted)."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    clk = FakeClock()
    eng = _engine(cfg, model, params, clock=clk)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8,
                       deadline=5.0))
    for _ in range(3):
        eng.step()
    emitted = len(eng.results[0].tokens)
    assert 0 < emitted < 8
    clk.t = 10.0                                  # blow the SLO
    eng.step()
    assert eng.idle
    r = eng.results[0]
    assert r.finish_reason == "deadline" and r.partial
    assert r.tokens == _oracle(model, cfg, params, prompt, 8)[:emitted]
    assert eng.n_deadline_aborts == 1 and 0 in eng.shed
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_queued_request_past_deadline_is_shed_explicitly(dense_setup):
    """Shedding is never silent: the refused request lands in ``results``
    with finish_reason='shed' and its rid in ``engine.shed``."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 4])
    clk = FakeClock()
    eng = _engine(cfg, model, params, num_pages=5, max_len=16, clock=clk)
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=8))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=4,
                       deadline=2.0))             # queued: pool fits one
    eng.step()
    assert len(eng.active) == 1 and len(eng.pending) == 1
    clk.t = 3.0                                   # rid 1 expires in queue
    res = eng.run()
    assert res[1].finish_reason == "shed" and res[1].tokens == []
    assert eng.shed == [1] and eng.n_shed == 1
    assert res[0].tokens == _oracle(model, cfg, params, prompts[0], 8)
    assert set(res) == {0, 1}                     # nobody silently dropped


def test_provably_unmeetable_slo_shed_at_admission(dense_setup):
    """Admission control sheds a request whose deadline cannot be met even
    with zero queue delay (max_new x rolling step clock overshoots)."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 4])
    clk = FakeClock()
    eng = _engine(cfg, model, params, clock=clk)
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
    def _stepped():
        clk.t += 1.0                              # each engine step: 1s
        return None
    real_decode = eng._decode_step
    eng._decode_step = lambda: (real_decode(), _stepped())[0]
    eng.step(); eng.step()                        # step clock EMA warms up
    assert eng._step_ema and eng._step_ema > 0.5
    # 8 tokens x ~1s/step >> 3s of headroom: provably unmeetable
    eng.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=8,
                       deadline=clk.t + 3.0))
    res = eng.run()
    assert res[1].finish_reason == "shed" and eng.n_shed == 1
    assert res[0].tokens == _oracle(model, cfg, params, prompts[0], 4)


def test_shedding_off_never_sheds(dense_setup):
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    clk = FakeClock()
    eng = _engine(cfg, model, params, clock=clk, shedding=False)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                       deadline=0.0))             # already expired
    clk.t = 99.0
    res = eng.run()
    assert res[0].finish_reason == "length"
    assert res[0].tokens == _oracle(model, cfg, params, prompt, 6)


def test_small_request_bypasses_blocked_giant(dense_setup):
    """Head-of-line bypass: a giant blocked on pages does not starve a
    small request that fits *now*; with ``hol_bypass=0`` admission is
    strict FIFO and the small one waits."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 7, 3])
    reqs = lambda: [  # noqa: E731
        Request(rid=0, prompt=prompts[0], max_new_tokens=6),   # holds pool
        Request(rid=1, prompt=prompts[1], max_new_tokens=4),   # giant: 3 pg
        Request(rid=2, prompt=prompts[2], max_new_tokens=1),   # small: 1 pg
    ]
    bypass = _engine(cfg, model, params, num_pages=5, max_len=12)
    res = bypass.serve(reqs())
    assert res[2].admitted < res[1].admitted      # small went around
    for i, g in ((0, 6), (1, 4), (2, 1)):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i], g), i

    fifo = _engine(cfg, model, params, num_pages=5, max_len=12,
                   hol_bypass=0)
    res = fifo.serve(reqs())
    assert res[2].admitted >= res[1].admitted     # strict FIFO: giant first
    for i, g in ((0, 6), (1, 4), (2, 1)):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i], g), i


def test_priority_preempts_lower_inflight(dense_setup):
    """A high-priority arrival evicts a lower-priority in-flight victim for
    its pages; the victim restores afterwards and both streams stay
    oracle-identical."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 5])
    eng = _engine(cfg, model, params, num_pages=5, max_len=12)
    res = eng.serve([
        Request(rid=0, prompt=prompts[0], max_new_tokens=6, priority=0),
        Request(rid=1, prompt=prompts[1], max_new_tokens=6, priority=5),
    ], arrival_steps=[0, 2])
    assert res[0].preemptions == 1 and res[1].preemptions == 0
    assert res[1].admitted < res[0].token_times[-1]   # jumped the line
    for i in range(2):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i], 6), i
    assert eng.n_preempted == 1 and eng.n_restored == 1
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


# ------------------------------------------------ fault-injected serving

def test_chaos_injector_is_order_independent():
    spec = ServeFaultSpec(crash_prob=0.2, hang_prob=0.3, seed=5)
    inj = ServeFaultInjector(spec)
    forward = [inj.decide(s) for s in range(40)]
    shuffled = {s: ServeFaultInjector(spec).decide(s)
                for s in np.random.default_rng(0).permutation(40)}
    assert forward == [shuffled[s] for s in range(40)]
    assert CRASH in forward and HANG in forward and None in forward


def test_parse_chaos():
    assert parse_chaos("hang:3,crash:6") == (ServeDrill(HANG, 3),
                                             ServeDrill(CRASH, 6))
    with pytest.raises(ValueError):
        parse_chaos("explode:3")
    with pytest.raises(ValueError):
        parse_chaos("hang:x")


@pytest.mark.parametrize("attention", ["dense", "paged"])
def test_crash_recovery_token_identical(dense_setup, attention):
    """An injected decode-step crash under supervision: the engine rebuilds
    pools+allocator from host truth, re-prefills every survivor, and all
    completed streams equal the fault-free oracle bit-for-bit."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 1, 7])
    gens = [6, 4, 8]
    eng = _engine(cfg, model, params, attention=attention,
                  faults=ServeFaultSpec(drills=(ServeDrill(CRASH, 4),)))
    res = eng.serve([Request(rid=i, prompt=prompts[i],
                             max_new_tokens=gens[i]) for i in range(3)],
                    arrival_steps=[0, 1, 2])
    assert eng.n_rebuilds == 1
    [rep] = eng.recoveries
    assert rep.cause == CRASH and rep.step == 4 and rep.n_survivors >= 1
    assert rep.first_token_s >= 0.0
    for i in range(3):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i],
                                        gens[i]), (attention, i)
        assert res[i].finish_reason == "length"
    assert eng.alloc.live_pages == 0 and eng._reserved == 0


def test_hang_recovery_via_watchdog(dense_setup):
    """An injected decode hang is classified by the watchdog deadline, then
    recovered exactly like a crash — streams stay oracle-identical."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 3])
    # warm the jit caches first so a cold compile can never be
    # misclassified as the injected hang
    warm = _engine(cfg, model, params)
    warm.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=2)
                for i in range(2)])
    eng = _engine(cfg, model, params, watchdog_s=1.0,
                  faults=ServeFaultSpec(drills=(ServeDrill(HANG, 3),)))
    res = eng.serve([Request(rid=i, prompt=prompts[i], max_new_tokens=5)
                     for i in range(2)])
    assert eng.n_rebuilds == 1
    assert eng.recoveries[0].cause == HANG
    assert eng.recoveries[0].detect_s >= 1.0      # the watchdog deadline
    for i in range(2):
        assert res[i].tokens == _oracle(model, cfg, params, prompts[i], 5), i


def test_unsupervised_fault_raises_with_state_dump(dense_setup):
    """supervise=False: the fault propagates loudly (the CLI maps it to
    exit 2) carrying a full engine-state dump for postmortems."""
    cfg, model, params = dense_setup
    [prompt] = _prompts(cfg, [5])
    eng = _engine(cfg, model, params, supervise=False,
                  faults=ServeFaultSpec(drills=(ServeDrill(CRASH, 2),)))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    with pytest.raises(ServeFault, match="engine state at fault"):
        eng.run()


def test_hang_spec_requires_watchdog(dense_setup):
    cfg, model, params = dense_setup
    with pytest.raises(ValueError, match="watchdog"):
        _engine(cfg, model, params,
                faults=ServeFaultSpec(drills=(ServeDrill(HANG, 1),)))


def test_run_exhaustion_dumps_engine_state(dense_setup):
    """The stuck-engine diagnostic replaces the bare RuntimeError: it names
    queued/active rids, page occupancy, and reservation totals."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 4])
    eng = _engine(cfg, model, params)
    eng.submit(Request(rid=3, prompt=prompts[0], max_new_tokens=8))
    eng.submit(Request(rid=9, prompt=prompts[1], max_new_tokens=8))
    with pytest.raises(RuntimeError) as ei:
        eng.run(max_steps=2)
    msg = str(ei.value)
    assert "not idle after 2 steps" in msg
    assert "3(len=" in msg and ("9(len=" in msg or "rids=[9]" in msg)
    assert "free=" in msg and "reserved=" in msg and "shed=" in msg


# ================================================================ CLI shim

def test_cli_continuous_smoke(capsys):
    res = launch_serve.main([
        "--arch", "deepseek-7b", "--engine", "continuous",
        "--attention", "paged", "--batch", "2", "--prompt-len", "4",
        "--gen", "3", "--page-size", "4", "--num-pages", "32"])
    assert len(res) == 2
    assert all(len(r.tokens) == 3 for r in res.values())
    assert "served 2 requests" in capsys.readouterr().out


def test_cli_static_smoke(capsys):
    toks = launch_serve.main([
        "--arch", "deepseek-7b", "--batch", "2", "--prompt-len", "4",
        "--gen", "3"])
    assert toks.shape == (2, 3)
