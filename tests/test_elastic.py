"""Elastic production engine: detection, reshrink, rollback recovery.

Covers the full device-loss path of ``repro.launch.elastic`` +
``repro.launch.mesh.plan_reshrink`` + the engine's recovery orchestration:

* seeded fault verdicts are pure functions of ``(seed, step, device)`` —
  order-independent and replay-stable (the invariant that makes a
  deterministic drill meaningful);
* the watchdog classifies a hung collective within its deadline;
* the reshrink planner degrades data first, honors batch divisibility,
  keeps determinism, and raises on exhaustion;
* checkpoint integrity (per-array SHA-256) and durability: a truncated or
  bit-flipped step dir is skipped with a warning and the restore falls
  back to the newest valid step; GC never collects the rollback anchor;
* end-to-end recovery drills through the real CLI: with ``--elastic`` a
  scripted kill recovers and the final parameters are **bit-equal** to a
  fresh run launched from the rollback checkpoint on the shrunken mesh;
  without ``--elastic`` the same drill fails loudly (the watchdog fires
  within its deadline — never a silent hang).
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.runtime_model import expected_recovery_overhead, recovery_cost
from repro.launch.elastic import (HANG, KILL, DeviceFaultInjector,
                                  DeviceFaultSpec, DeviceLost, Drill,
                                  RecoveryReport, WatchdogTimeout,
                                  call_with_deadline, parse_drill)

_ENV_BASE = dict(os.environ, PYTHONPATH=os.path.abspath("src"),
                 XLA_FLAGS="--xla_force_host_platform_device_count=8")


# ------------------------------------------------------------- drill parsing

def test_parse_drill():
    assert parse_drill("kill-device:3") == Drill(KILL, 3, 0)
    assert parse_drill("kill-device:3:5") == Drill(KILL, 3, 5)
    assert parse_drill("hang-device:0:2") == Drill(HANG, 0, 2)


@pytest.mark.parametrize("bad", ["kill-device", "kill-device:x",
                                 "explode-device:3", "kill-device:1:2:3",
                                 "hang-device:-1"])
def test_parse_drill_rejects(bad):
    with pytest.raises(ValueError):
        parse_drill(bad)


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        DeviceFaultSpec(kill_prob=1.0)
    with pytest.raises(ValueError):
        DeviceFaultSpec(kill_prob=0.6, hang_prob=0.5)
    with pytest.raises(ValueError):
        Drill("explode", 1)


# -------------------------------------------------- injector (verdict purity)

def test_injector_verdicts_are_order_independent():
    """decide(step, device) is a pure function of (seed, step, device): the
    verdict never depends on how many other pairs were consulted first, so
    a rolled-back replay re-draws identical faults."""
    spec = DeviceFaultSpec(kill_prob=0.2, hang_prob=0.2, seed=7)
    keys = [(s, d) for s in range(20) for d in range(8)]
    inj = DeviceFaultInjector(spec)
    serial = [inj.decide(*k) for k in keys]
    # reversed consultation order, fresh injector: same verdicts
    reversed_ = [DeviceFaultInjector(spec).decide(*k)
                 for k in reversed(keys)][::-1]
    assert serial == reversed_
    # re-issued after an "eviction" (subset re-consulted mid-stream)
    replay = [inj.decide(*k) for k in keys[40:]]
    assert replay == serial[40:]
    # seeded: a different seed gives a different fault pattern
    other = [DeviceFaultInjector(
        DeviceFaultSpec(kill_prob=0.2, hang_prob=0.2, seed=8)).decide(*k)
        for k in keys]
    assert other != serial
    kinds = set(serial)
    assert KILL in kinds and HANG in kinds and None in kinds


def test_injector_drills_win_and_first_fault_scans_in_order():
    spec = DeviceFaultSpec(drills=(Drill(KILL, 3, 1), Drill(HANG, 3, 0)))
    inj = DeviceFaultInjector(spec)
    assert inj.decide(3, 1) == KILL
    assert inj.decide(3, 0) == HANG
    assert inj.decide(2, 1) is None
    # device index order is canonical: device 0's hang wins the scan
    assert inj.first_fault(3, 8) == (0, HANG)
    assert inj.first_fault(4, 8) is None


# ------------------------------------------------------------------ watchdog

def test_call_with_deadline_passes_value_and_errors():
    assert call_with_deadline(lambda a, b: a + b, (2, 3),
                              deadline_s=5.0) == 5

    def boom():
        raise KeyError("inner")
    with pytest.raises(KeyError):
        call_with_deadline(boom, deadline_s=5.0)


def test_watchdog_fires_within_deadline():
    """A hung call is classified within ~the deadline, not 'eventually'."""
    t0 = time.perf_counter()
    with pytest.raises(WatchdogTimeout):
        call_with_deadline(time.sleep, (30.0,), deadline_s=0.3,
                           what="hung collective")
    took = time.perf_counter() - t0
    assert 0.25 <= took < 3.0      # fired at ~deadline, far before the sleep


def test_device_lost_carries_the_diagnosis():
    e = DeviceLost(7, 3, HANG)
    assert (e.step, e.device, e.cause) == (7, 3, HANG)
    assert "device 3 lost at step 7" in str(e)


# ------------------------------------------------- recovery cost accounting

def test_recovery_report_total_and_dict():
    r = RecoveryReport(step=9, device=2, cause=KILL, rollback_step=8,
                       rollback_depth=1, detect_s=0.1, plan_s=0.2,
                       restore_s=0.3, rejit_s=0.4, replay_s=0.5)
    assert r.total_s == pytest.approx(1.5)
    d = r.as_dict()
    assert d["total_s"] == pytest.approx(1.5)
    assert d["rollback_depth"] == 1


def test_recovery_cost_terms():
    # rollback depth x step clock + re-jit + the measured small terms
    assert recovery_cost(2.0, 3, 10.0) == pytest.approx(16.0)
    assert recovery_cost(2.0, 0, 10.0, restore_s=1.0,
                         detect_s=0.5, replay_s=0.5) == pytest.approx(12.0)
    with pytest.raises(ValueError):
        recovery_cost(1.0, -1, 0.0)


def test_expected_recovery_overhead_scales_with_ckpt_cadence():
    # deeper cadence -> deeper expected rollback -> more overhead per step
    lo = expected_recovery_overhead(1.0, loss_prob=1e-3, ckpt_every=1,
                                    rejit_s=30.0)
    hi = expected_recovery_overhead(1.0, loss_prob=1e-3, ckpt_every=101,
                                    rejit_s=30.0)
    assert lo == pytest.approx(1e-3 * 30.0)
    assert hi == pytest.approx(1e-3 * (30.0 + 50.0))
    assert expected_recovery_overhead(1.0, loss_prob=0.0, ckpt_every=10,
                                      rejit_s=30.0) == 0.0
    with pytest.raises(ValueError):
        expected_recovery_overhead(1.0, loss_prob=1.0, ckpt_every=10,
                                   rejit_s=0.0)


# ------------------------------------------- checkpoint integrity/durability

def _tree(seed):
    r = np.random.default_rng(seed)
    return {"params": {"w": r.normal(size=(4, 4)).astype(np.float32),
                       "b": r.normal(size=(4,)).astype(np.float32)},
            "opt_state": {"m": r.normal(size=(4, 4)).astype(np.float32)}}


def test_checkpoint_truncation_falls_back_to_newest_valid(tmp_path):
    """Regression: a deliberately truncated npz is skipped with a warning
    and latest_step/restore fall back to the newest valid step; naming the
    corrupt step explicitly raises instead of silently substituting."""
    from repro.checkpoint import latest_step, load_checkpoint
    from repro.checkpoint.ckpt import save_checkpoint
    d = str(tmp_path)
    save_checkpoint(d, 2, _tree(0))
    save_checkpoint(d, 4, _tree(1))
    assert latest_step(d) == 4
    npz = os.path.join(d, "step_00000004", "arrays.npz")
    with open(npz, "r+b") as f:                  # truncate mid-payload
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.warns(UserWarning, match="corrupt/truncated"):
        assert latest_step(d) == 2
    with pytest.warns(UserWarning):
        got, meta = load_checkpoint(d, _tree(9), None)
    assert meta["step"] == 2
    np.testing.assert_array_equal(got["params"]["w"], _tree(0)["params"]["w"])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        load_checkpoint(d, _tree(9), 4)


def test_checkpoint_checksum_detects_silent_payload_swap(tmp_path):
    """A payload whose bytes changed under an intact meta (the bit-flip
    model) fails SHA-256 verification."""
    from repro.checkpoint import verify_checkpoint
    from repro.checkpoint.ckpt import save_checkpoint
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _tree(0))
    assert verify_checkpoint(path)
    t = _tree(0)
    flipped = [np.asarray(x) for x in
               [t["params"]["b"], t["params"]["w"] + 1, t["opt_state"]["m"]]]
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(flipped)})
    assert not verify_checkpoint(path)


def test_gc_keeps_newest_valid_and_protected(tmp_path):
    from repro.checkpoint import gc_checkpoints, latest_step
    from repro.checkpoint.ckpt import save_checkpoint
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        save_checkpoint(d, s, _tree(s))
    npz = os.path.join(d, "step_00000004", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(10)                           # newest step is corrupt
    # keep=2 retains the 2 newest *valid* (2, 3); the corrupt 4 is
    # collected, never counted against retention; protected 1 survives
    deleted = gc_checkpoints(d, 2, protect=[1])
    assert deleted == [4]
    deleted = gc_checkpoints(d, 1, protect=[1])
    assert deleted == [2]
    assert latest_step(d) == 3
    with pytest.raises(ValueError):
        gc_checkpoints(d, 0)


# --------------------------------------------------- reshrink planner (8dev)

RESHRINK_SCRIPT = textwrap.dedent("""
    import json, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.mesh import (ReshrinkError, make_host_mesh,
                                   make_multipod_debug_mesh, plan_reshrink)

    out = {}
    mesh = make_host_mesh()                      # (4, 2) = (data, model)
    plan = plan_reshrink(mesh, [0], global_batch=8)
    out["host_shape"] = list(plan.new_shape)     # data degrades, model kept
    out["host_degraded"] = list(plan.degraded_axes)
    out["host_idle"] = plan.n_idle
    out["lost_absent"] = all(d.id != 0 for d in plan.mesh.devices.flatten())

    # batch divisibility constrains the surviving data width: batch=6 over
    # 6 survivors -> (3, 2); prime batch=7 -> data collapses to 1
    out["b6_shape"] = list(plan_reshrink(mesh, [0, 7],
                                         global_batch=6).new_shape)
    out["b7_shape"] = list(plan_reshrink(mesh, [0],
                                         global_batch=7).new_shape)

    mp = make_multipod_debug_mesh()              # (2, 2, 2) pod/data/model
    mplan = plan_reshrink(mp, [3], global_batch=8)
    out["mp_shape"] = list(mplan.new_shape)
    out["mp_axes"] = list(mplan.axis_names)

    again = plan_reshrink(mesh, [0], global_batch=8)
    out["deterministic"] = (
        [d.id for d in plan.mesh.devices.flatten()]
        == [d.id for d in again.mesh.devices.flatten()])

    try:
        plan_reshrink(mesh, [d.id for d in mesh.devices.flatten()],
                      global_batch=8)
        out["exhaustion_raises"] = False
    except ReshrinkError:
        out["exhaustion_raises"] = True
    print("RESULT", json.dumps(out))
""")


def test_plan_reshrink_degrades_data_first():
    proc = subprocess.run([sys.executable, "-c", RESHRINK_SCRIPT],
                          env=_ENV_BASE, capture_output=True, text=True,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line.split("RESULT ")[1])
    assert out["host_shape"] == [2, 2]          # (4,2) - 1 dev -> (2,2)
    assert out["host_degraded"] == ["data"]     # model axis untouched
    assert out["host_idle"] == 3
    assert out["lost_absent"]
    assert out["b6_shape"] == [3, 2]
    assert out["b7_shape"] == [1, 2]            # prime batch: data -> 1
    assert out["mp_shape"] == [2, 1, 2]         # data before pod/model
    assert out["mp_axes"] == ["pod", "data", "model"]
    assert out["deterministic"]
    assert out["exhaustion_raises"]


# ------------------------------------------------- recovery drills (the CLI)

def test_recovery_drill_elastic_is_bit_equal(tmp_path):
    """Acceptance drill: seeded kill at step 3 with --ckpt-every 2 rolls
    back to step 2 and the elastic run's final params are bit-equal to a
    fresh run launched from that checkpoint on the shrunken mesh (the CLI
    verifies and prints the verdict)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--steps", "4",
         "--mesh", "debug", "--nodes", "2", "--batch", "4", "--seq", "32",
         "--elastic", "--drill", "kill-device:3",
         "--ckpt", str(tmp_path), "--ckpt-every", "2", "--ckpt-keep", "2"],
        env=_ENV_BASE, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    drill = [l for l in proc.stdout.splitlines()
             if l.startswith("RECOVERY_DRILL")][0]
    assert "bit_equal=true" in drill
    assert "rollback_step=2" in drill           # floor(3/2)*2
    rec = [l for l in proc.stdout.splitlines() if l.startswith("recovery:")]
    assert len(rec) == 1 and "'rollback_depth': 1" in rec[0]


def test_drill_without_elastic_fails_loudly_via_watchdog(tmp_path):
    """Without --elastic a hung collective must not hang the run: the
    watchdog classifies it within the deadline and the CLI exits loudly
    with the DeviceLost diagnostic."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--steps", "3",
         "--mesh", "debug", "--nodes", "2", "--batch", "4", "--seq", "32",
         "--drill", "hang-device:1", "--watchdog-s", "3"],
        env=_ENV_BASE, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 2
    assert "FATAL" in proc.stderr
    assert "lost at step 1 (hang)" in proc.stderr
    assert "--elastic" in proc.stderr           # points at the recovery path


@pytest.mark.slow
def test_recovery_drill_hang_elastic(tmp_path):
    """Nightly: the hang flavor end-to-end — watchdog detection feeding the
    same reshrink/rollback path, bit-equal verdict included."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--steps", "4",
         "--mesh", "host", "--nodes", "2", "--batch", "4", "--seq", "32",
         "--elastic", "--drill", "hang-device:2", "--watchdog-s", "5",
         "--ckpt", str(tmp_path), "--ckpt-every", "2"],
        env=_ENV_BASE, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    drill = [l for l in proc.stdout.splitlines()
             if l.startswith("RECOVERY_DRILL")][0]
    assert "bit_equal=true" in drill
