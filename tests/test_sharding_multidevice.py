"""Multi-device (4 virtual CPU devices) integration: the sharded TL step
produces the SAME numbers as the single-device step, and the sharding rules
produce valid specs for every arch's param tree.

Runs in a subprocess so the forced device count never leaks into other tests.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import json
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.core.tl_step import make_train_step, train_shardings
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.optim import sgd
    from repro.configs.shapes import InputShape

    arch = os.environ["TEST_ARCH"]
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    p = m.init(key)
    opt = sgd(0.1)
    st = opt.init(p)
    B, S = 4, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model)) * 0.02

    step = make_train_step(m, cfg, opt)
    p1, st1, loss1 = jax.jit(step)(p, st, batch)       # single-logical-device

    mesh = make_debug_mesh(2, 2)
    shape = InputShape("t", S, B, "train")
    with mesh:
        in_sh, out_sh = train_shardings(p, st, cfg, mesh, shape,
                                        with_embeds=bool(cfg.frontend))
        p2, st2, loss2 = jax.jit(step, in_shardings=in_sh,
                                 out_shardings=out_sh)(p, st, batch)

    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), p1, p2)))
    print("RESULT", json.dumps({"loss1": float(loss1), "loss2": float(loss2),
                                 "err": err}))
""")
import json as _json


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-v3-671b",
                                  "mamba2-780m", "recurrentgemma-9b"])
def test_sharded_step_matches_single_device(arch):
    env = dict(os.environ, TEST_ARCH=arch,
               PYTHONPATH=os.path.abspath("src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    data = _json.loads(line.split("RESULT ")[1])
    assert abs(data["loss1"] - data["loss2"]) < 1e-4
    assert data["err"] < 5e-3, data


def test_param_specs_cover_all_archs():
    """Every arch's param tree gets a valid spec (no exceptions, correct
    ndim) under both mesh layouts."""
    import jax
    from jax.sharding import PartitionSpec
    from repro.configs import ARCHS, get_config
    from repro.dist.sharding import param_pspec
    from repro.models import build_model

    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        m = build_model(cfg)
        params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
        specs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: param_pspec(path, leaf, cfg), params)
        for leaf, spec in zip(jax.tree.leaves(params),
                              jax.tree.leaves(
                                  specs, is_leaf=lambda x: isinstance(
                                      x, PartitionSpec))):
            assert isinstance(spec, PartitionSpec)
            assert len(spec) <= leaf.ndim
