"""Analysis layer: HLO parser trip counts, roofline math, report rendering,
and the virtual-batch reassembly scatter accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_flops import analyze
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     model_flops,
                                     predict_reassembly_hbm_bytes)
from repro.analysis.report import fmt_bytes, roofline_table
from repro.configs import get_config, get_shape

HLO = """
HloModule test, is_scheduled=true

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
}

%cond (arg.1: (s32[], f32[8,16])) -> pred[] {
  %arg.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %i.1 = s32[] get-tuple-element(%arg.1), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i.1, %n), direction=LT
}

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,16]{1,0}) tuple(%z, %p)
  %w2 = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_while_trip_count_multiplies_flops_and_collectives():
    c = analyze(HLO)
    # dot: 2*8*16*16 flops per trip, 7 trips
    assert c.flops == pytest.approx(7 * 2 * 8 * 16 * 16)
    # all-reduce: 8*16*4 bytes * 2 (convention) * 7 trips
    assert c.coll["all-reduce"] == pytest.approx(7 * 8 * 16 * 4 * 2)


def test_analyzer_matches_real_scan_workload():
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0].sum()
    L, B, D = 5, 32, 64
    comp = jax.jit(jax.grad(f, argnums=1)).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    c = analyze(comp.as_text())
    expected = 3 * L * 2 * B * D * D
    assert 0.8 < c.flops / expected < 1.4


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="s", mesh="single", chips=256,
                 flops_per_chip=PEAK_FLOPS, bytes_per_chip=HBM_BW * 10,
                 coll_bytes_per_chip=ICI_BW, model_flops_global=PEAK_FLOPS * 128)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(10.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.bottleneck == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_semantics():
    cfg = get_config("deepseek-v3-671b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    n_act = cfg.n_active_params()
    assert tr == pytest.approx(6 * n_act * 4096 * 256)
    assert pf == pytest.approx(2 * n_act * 32768 * 32)
    assert dc == pytest.approx(2 * n_act * 128)


# ------------------------------------------------- reassembly accounting

def test_scatter_accounting_counts_generic_scatters():
    """``.at[perm].set`` compiles to a generic scatter; the analyzer must
    see it (count + result bytes) so the reassembly assertion below has
    teeth."""
    def f(x, p):
        return jnp.zeros_like(x).at[p].set(x)
    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 4), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.int32)).compile().as_text()
    c = analyze(hlo)
    assert c.n_scatter == 1
    assert c.scatter_bytes == 8 * 4 * 4


def test_predict_reassembly_hbm_bytes_halves_under_pallas():
    xla = predict_reassembly_hbm_bytes(100.0, 10.0, 100.0, strategy="xla")
    pallas = predict_reassembly_hbm_bytes(100.0, 10.0, 100.0,
                                          strategy="pallas")
    assert xla["total"] == 2 * 210.0 and xla["write_multiplier"] == 2.0
    assert pallas["total"] == 210.0 and pallas["write_multiplier"] == 1.0
    assert xla["x1"] == 2 * pallas["x1"] == 200.0
    with pytest.raises(ValueError):
        predict_reassembly_hbm_bytes(1.0, strategy="bogus")


def _fused_step_hlo(reassembly):
    """Compile the orchestrator's fused centralized-BP step for one real
    virtual batch (arguments assembled exactly as ``_train_batch_fused``
    does) and return (HLO text, x1 byte size)."""
    from repro.configs.paper_models import DATRET
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.plan import PlanSpec
    from repro.core.transport import Transport
    from repro.models.small import SmallModel
    from repro.optim import sgd

    model = SmallModel(DATRET)
    r = np.random.default_rng(0)
    nodes = [TLNode(i, model,
                    r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
                    r.integers(0, DATRET.n_classes, n))
             for i, n in enumerate([9, 7])]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=16, plan=PlanSpec(seed=0),
                          reassembly=reassembly)
    orch.initialize(jax.random.PRNGKey(0))
    vb = orch.build_plan(0).batches[0]
    node_by_id = {n.node_id: n for n in orch.nodes}
    results, order = orch._collect_visits(vb, node_by_id)
    segs = [results[nid][0] for nid in order]
    wires = [results[nid][1] for nid in order]
    leaf_idx = orch._gw1_leaf_indices()
    perm = jnp.asarray(np.concatenate(
        [s.batch_positions for s in segs]).astype(np.int32))
    x1_cat = jnp.concatenate([w["x1"] for w in wires])
    dL_cat = jnp.concatenate([w["delta_L"] for w in wires])
    dx1_cat = jnp.concatenate([w["dx1"] for w in wires])
    gw1s = tuple(orch._as_leaf_dict(w["gw1"], leaf_idx) for w in wires)
    hlo = orch._get_fused_step().lower(
        orch.params, orch.opt_state, x1_cat, dL_cat, dx1_cat, perm,
        gw1s).compile().as_text()
    return hlo, x1_cat.size * x1_cat.dtype.itemsize


def test_fused_step_reassembly_materializes_x1_once_under_pallas():
    """The ROADMAP/acceptance contract: with ``reassembly="pallas"`` the
    compiled fused step materializes the reassembled X^(1) once — no
    generic scatter op (and hence no zeros-init + row-update double write)
    survives compilation.  The XLA strategy keeps its three payload
    scatters, whose accounted bytes cover the reassembled buffers."""
    hlo_xla, x1_bytes = _fused_step_hlo("xla")
    hlo_pallas, _ = _fused_step_hlo("pallas")
    cx, cp = analyze(hlo_xla), analyze(hlo_pallas)

    # xla: one scatter per payload tensor (x1, delta_L, dx1-consistency),
    # each materializing its full reassembled result buffer
    assert cx.n_scatter >= 3, cx
    assert cx.scatter_bytes >= 2 * x1_bytes, cx     # x1 + dx1 at least

    # pallas: the X^(1) intermediate no longer materializes via scatter
    assert cp.n_scatter == 0, cp
    assert cp.scatter_bytes == 0, cp

    # the roofline model's write-traffic prediction mirrors the drop
    assert predict_reassembly_hbm_bytes(x1_bytes, strategy="pallas")["x1"] \
        == predict_reassembly_hbm_bytes(x1_bytes, strategy="xla")["x1"] / 2


def test_report_renders_skips_and_rows():
    arts = {
        ("a1", "train_4k", "single"): {
            "arch": "a1", "shape": "train_4k", "mesh": "single",
            "status": "ok", "t_compute": 1.0, "t_memory": 2.0,
            "t_collective": 0.5, "bottleneck": "memory",
            "useful_flops_ratio": 0.7, "peak_memory_per_chip": 2**30,
            "coll_breakdown": {"all-reduce": 2**20}},
        ("a1", "long_500k", "single"): {
            "arch": "a1", "shape": "long_500k", "mesh": "single",
            "status": "skipped"},
    }
    tbl = roofline_table(arts, "single")
    assert "**memory**" in tbl and "designed skip" in tbl
    assert fmt_bytes(2**30) == "1.0G" and fmt_bytes(2**20) == "1M"
