"""Analysis layer: HLO parser trip counts, roofline math, report rendering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_flops import Costs, analyze, parse_module
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     model_flops)
from repro.analysis.report import fmt_bytes, roofline_table
from repro.configs import get_config, get_shape

HLO = """
HloModule test, is_scheduled=true

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
}

%cond (arg.1: (s32[], f32[8,16])) -> pred[] {
  %arg.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %i.1 = s32[] get-tuple-element(%arg.1), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i.1, %n), direction=LT
}

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,16]{1,0}) tuple(%z, %p)
  %w2 = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_while_trip_count_multiplies_flops_and_collectives():
    c = analyze(HLO)
    # dot: 2*8*16*16 flops per trip, 7 trips
    assert c.flops == pytest.approx(7 * 2 * 8 * 16 * 16)
    # all-reduce: 8*16*4 bytes * 2 (convention) * 7 trips
    assert c.coll["all-reduce"] == pytest.approx(7 * 8 * 16 * 4 * 2)


def test_analyzer_matches_real_scan_workload():
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0].sum()
    L, B, D = 5, 32, 64
    comp = jax.jit(jax.grad(f, argnums=1)).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    c = analyze(comp.as_text())
    expected = 3 * L * 2 * B * D * D
    assert 0.8 < c.flops / expected < 1.4


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="s", mesh="single", chips=256,
                 flops_per_chip=PEAK_FLOPS, bytes_per_chip=HBM_BW * 10,
                 coll_bytes_per_chip=ICI_BW, model_flops_global=PEAK_FLOPS * 128)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(10.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.bottleneck == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_semantics():
    cfg = get_config("deepseek-v3-671b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    n_act = cfg.n_active_params()
    assert tr == pytest.approx(6 * n_act * 4096 * 256)
    assert pf == pytest.approx(2 * n_act * 32768 * 32)
    assert dc == pytest.approx(2 * n_act * 128)


def test_report_renders_skips_and_rows():
    arts = {
        ("a1", "train_4k", "single"): {
            "arch": "a1", "shape": "train_4k", "mesh": "single",
            "status": "ok", "t_compute": 1.0, "t_memory": 2.0,
            "t_collective": 0.5, "bottleneck": "memory",
            "useful_flops_ratio": 0.7, "peak_memory_per_chip": 2**30,
            "coll_breakdown": {"all-reduce": 2**20}},
        ("a1", "long_500k", "single"): {
            "arch": "a1", "shape": "long_500k", "mesh": "single",
            "status": "skipped"},
    }
    tbl = roofline_table(arts, "single")
    assert "**memory**" in tbl and "designed skip" in tbl
    assert fmt_bytes(2**30) == "1.0G" and fmt_bytes(2**20) == "1M"
