"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Shapes/dtypes swept parametrically + hypothesis property tests on the
quantizer's error bound.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.act_compress import (CODECS, compress, compressed_bytes,
                                        decompress, dequantize_rows_ref,
                                        ef_compress, quantize_rows_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rglru import rglru_ref, rglru_scan
from repro.kernels.ssd import ssd, ssd_ref_bh
from repro.kernels.vb_scatter import (permute_rows, scatter_rows,
                                      scatter_rows_ref, vb_scatter,
                                      vb_scatter_ref)


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("B,S,H,KV,D,win,dtype", [
    (1, 128, 2, 2, 64, 0, jnp.float32),
    (2, 256, 4, 2, 64, 0, jnp.float32),
    (1, 192, 2, 1, 128, 0, jnp.float32),       # padding path (192 % 64 != 0)
    (1, 256, 2, 1, 128, 64, jnp.float32),      # sliding window
    (1, 128, 2, 2, 64, 0, jnp.bfloat16),
])
def test_flash_vs_ref(B, S, H, KV, D, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = flash_attention(q, k, v, causal=True, window=win,
                          block_q=64, block_k=64)
    rep = H // KV
    kr = jnp.repeat(k, rep, 2) if rep > 1 else k
    vr = jnp.repeat(v, rep, 2) if rep > 1 else v
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, D).astype(jnp.float32),
        kr.transpose(0, 2, 1, 3).reshape(B * H, S, D).astype(jnp.float32),
        vr.transpose(0, 2, 1, 3).reshape(B * H, S, D).astype(jnp.float32),
        scale=1 / math.sqrt(D), causal=True, window=win)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ----------------------------------------------------------------------- SSD

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 16, 8, 8),
    (2, 64, 3, 32, 16, 16),
    (1, 128, 1, 64, 32, 32),
])
def test_ssd_vs_sequential_ref(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, hT = ssd(x, dt, A_log, Bm, Cm, chunk=chunk)

    A = -jnp.exp(A_log)
    dA = (dt * A).transpose(0, 2, 1).reshape(B * H, S)
    xf = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, P)
    Bf = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cf = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    yr, hTr = ssd_ref_bh(dA, xf, Bf, Cf)
    yr = yr.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(hT.reshape(B * H, P, N)),
                               np.asarray(hTr), atol=2e-4, rtol=2e-4)


# --------------------------------------------------------------------- RG-LRU

@pytest.mark.parametrize("B,S,W,chunk", [(1, 32, 64, 8), (2, 48, 128, 16),
                                         (1, 40, 64, 16)])
def test_rglru_vs_ref(B, S, W, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W))
    h, hT = rglru_scan(a, b, chunk=chunk)
    hr = rglru_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr[:, -1]),
                               atol=1e-5)


# -------------------------------------------------------------- act compress

def test_quantizer_matches_ref_bitexact():
    x = jax.random.normal(jax.random.PRNGKey(3), (96, 192)) * 5
    payload = compress(x, block_rows=32)
    qr, sr = quantize_rows_ref(x)
    # scales match to 1 ulp (interpret-mode reduction order may differ);
    # quantized values may then differ by at most 1 level on ties
    np.testing.assert_allclose(np.asarray(payload["scale"]), np.asarray(sr),
                               rtol=1e-6)
    assert int(jnp.abs(payload["q"].astype(jnp.int32)
                       - qr.astype(jnp.int32)).max()) <= 1
    xr = decompress(payload, x.shape, block_rows=32)
    ref = dequantize_rows_ref(qr, sr)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(ref), atol=1e-6)


def test_fp8_quantizer_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(4), (96, 192)) * 5
    payload = compress(x, codec="fp8", block_rows=32)
    assert payload["q"].dtype == jnp.float8_e4m3fn
    qr, sr = quantize_rows_ref(x, codec="fp8")
    np.testing.assert_allclose(np.asarray(payload["scale"]), np.asarray(sr),
                               rtol=1e-6)
    xr = decompress(payload, x.shape, block_rows=32)
    ref = dequantize_rows_ref(qr, sr, codec="fp8")
    # a 1-ulp scale difference moves a dequantized element by at most one
    # e4m3 quantization level of its row
    tol = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / 16.0
    assert np.all(np.abs(np.asarray(xr) - np.asarray(ref)) <= tol + 1e-6)


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_quantizer_wire_bytes(codec):
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 128))
    payload = compress(x, codec=codec, block_rows=32)
    # 1 B/element (both rungs are single-byte dtypes) + 4 B f32 scale/row
    assert compressed_bytes(payload) == 64 * 128 + 64 * 4


def test_compress_rejects_non_float():
    with pytest.raises(TypeError, match="floating-point"):
        compress(jnp.arange(32).reshape(4, 8))
    with pytest.raises(TypeError, match="floating-point"):
        compress(np.zeros((4, 8), bool))


def test_bf16_roundtrip_regression():
    """bf16 in / bf16 out through the int8 wire: dtype is preserved and the
    error stays within the int8 grid bound (+ bf16's own half-ulp)."""
    x = (jax.random.normal(jax.random.PRNGKey(6), (32, 64)) * 3
         ).astype(jnp.bfloat16)
    payload = compress(x, block_rows=32)
    xr = decompress(payload, x.shape, out_dtype=jnp.bfloat16, block_rows=32)
    assert xr.dtype == jnp.bfloat16
    xf = np.asarray(x, np.float32)
    bound = np.abs(xf).max(axis=1, keepdims=True) * (0.5 / 127 + 2.0 ** -8)
    assert np.all(np.abs(np.asarray(xr, np.float32) - xf) <= bound + 1e-6)


@given(codec=st.sampled_from(sorted(CODECS)),
       value=st.floats(-1e3, 1e3, allow_nan=False, width=32),
       rows=st.integers(1, 5), cols=st.integers(1, 16), sends=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_ef_residual_of_constant_contracts_to_exact_zero(codec, value, rows,
                                                         cols, sends):
    """Lossless-in-the-limit, sharpest case: a constant tensor's
    error-feedback residual is *exactly* zero from the first send on (the
    scale = absmax formulation makes x/scale = ±1 and q/DENOM = ±1 exact),
    so the delivered tensor is bit-equal to the input every time."""
    x = jnp.full((rows, cols), np.float32(value))
    residual = None
    for _ in range(sends):
        _, delivered, residual = ef_compress(x, residual, codec=codec,
                                             block_rows=1)
        np.testing.assert_array_equal(np.asarray(delivered), np.asarray(x))
        assert np.all(np.asarray(residual) == 0.0)


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_ef_residual_drives_mean_delivered_to_x(codec):
    """Lossless-in-the-limit on *random* data: with error feedback, the
    running mean of delivered tensors converges to x (quantization error is
    carried forward, not discarded), far below the one-shot error bound."""
    x = jnp.asarray(np.random.default_rng(7).normal(size=(16, 32)) * 5,
                    jnp.float32)
    residual, acc = None, np.zeros(x.shape, np.float32)
    for k in range(1, 65):
        _, delivered, residual = ef_compress(x, residual, codec=codec,
                                             block_rows=16)
        acc += np.asarray(delivered)
    one_shot = np.abs(np.asarray(x)).max() / (127 if codec == "int8" else 16)
    err = np.abs(acc / 64 - np.asarray(x)).max()
    assert err < one_shot / 8


# ---------------------------------------------------------------- vb_scatter

def _segmented_perm(sizes, seed):
    """Concatenated ``batch_positions`` of a ragged node split: a shuffled
    partition of 0..N-1 handed out as contiguous per-node segments — the
    exact index stream the orchestrator's reassembly sees."""
    N = sum(sizes)
    pos = np.random.default_rng(seed).permutation(N)
    segs, o = [], 0
    for k in sizes:
        segs.append(pos[o:o + k])
        o += k
    return np.concatenate(segs).astype(np.int32)


@pytest.mark.parametrize("sizes", [[13, 8, 11], [5, 1, 2], [1, 1, 14]],
                         ids=["3nodes-uneven", "1sample-node", "two-1sample"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_vb_scatter_forward_and_vjp_match_ref(sizes, dtype):
    """Forward and custom_vjp backward are *exactly* (not just ULP-) equal
    to the pure-jnp scatter oracle over ragged node splits — the kernel and
    its transpose are pure row copies, so any difference is a bug."""
    N = sum(sizes)
    r = np.random.default_rng(N * 7 + 1)
    perm = jnp.asarray(_segmented_perm(sizes, seed=N))
    x1 = jnp.asarray(r.normal(size=(N, 4, 6))).astype(dtype)
    dL = jnp.asarray(r.normal(size=(N, 3))).astype(dtype)
    dx1 = jnp.asarray(r.normal(size=(N, 4, 6))).astype(dtype)

    for got, want in zip(vb_scatter(x1, dL, dx1, perm),
                         vb_scatter_ref(x1, dL, dx1, perm)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))

    # row-dependent weights make the cotangent row-distinguishable, so a
    # transposed-with-the-wrong-index backward cannot pass
    w = jnp.arange(1, N + 1, dtype=jnp.float32)

    def make_loss(scatter_fn):
        def loss(x1, dL, dx1):
            a, b, c = scatter_fn(perm, (x1, dL, dx1))
            return (w[:, None, None] * a.astype(jnp.float32) ** 2).sum() \
                + (w[:, None] * b.astype(jnp.float32)).sum() \
                + (w[:, None, None] * c.astype(jnp.float32) ** 3).sum()
        return loss

    g_kernel = jax.jit(jax.grad(make_loss(scatter_rows),
                                argnums=(0, 1, 2)))(x1, dL, dx1)
    g_ref = jax.jit(jax.grad(make_loss(scatter_rows_ref),
                             argnums=(0, 1, 2)))(x1, dL, dx1)
    for got, want in zip(g_kernel, g_ref):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))


def test_vb_scatter_mixed_int_rows_ride_the_fused_pass():
    """Integer rows (tokens/targets on the production path) scatter in the
    same kernel launch; differentiation skips them via float0 cotangents."""
    N = 9
    r = np.random.default_rng(3)
    perm = jnp.asarray(_segmented_perm([4, 1, 4], seed=11))
    h1 = jnp.asarray(r.normal(size=(N, 5)).astype(np.float32))
    tok = jnp.asarray(r.integers(0, 97, (N, 4)).astype(np.int32))

    hs, ts = scatter_rows(perm, (h1, tok))
    hr, tr = scatter_rows_ref(perm, (h1, tok))
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(hr))
    np.testing.assert_array_equal(np.asarray(ts), np.asarray(tr))

    def loss(h1):
        a, t = scatter_rows(perm, (h1, tok))
        return (a * t.astype(jnp.float32).sum(-1, keepdims=True)).sum()

    def loss_ref(h1):
        a, t = scatter_rows_ref(perm, (h1, tok))
        return (a * t.astype(jnp.float32).sum(-1, keepdims=True)).sum()

    np.testing.assert_array_equal(
        np.asarray(jax.jit(jax.grad(loss))(h1)),
        np.asarray(jax.jit(jax.grad(loss_ref))(h1)))


@pytest.mark.parametrize("mode", ["scatter", "gather"])
def test_permute_rows_column_blocking(mode):
    """Multi-column-block grid (narrow block_cols) and width-clamped narrow
    refs produce the same rows as the unblocked oracle in both routings."""
    N = 7
    r = np.random.default_rng(5)
    idx = jnp.asarray(r.permutation(N).astype(np.int32))
    wide = jnp.asarray(r.normal(size=(N, 20)).astype(np.float32))
    narrow = jnp.asarray(r.normal(size=(N, 3)).astype(np.float32))
    got_w, got_n = permute_rows(idx, wide, narrow, mode=mode, block_cols=8)
    if mode == "scatter":
        want_w = jnp.zeros_like(wide).at[idx].set(wide)
        want_n = jnp.zeros_like(narrow).at[idx].set(narrow)
    else:
        want_w, want_n = wide[idx], narrow[idx]
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))


@given(codec=st.sampled_from(sorted(CODECS)),
       rows=st.integers(1, 40), cols=st.integers(2, 64),
       scale=st.floats(1e-3, 1e3), zero_row=st.booleans())
@settings(max_examples=25, deadline=None)
def test_quantizer_error_bound(codec, rows, cols, scale, zero_row):
    """Property: per-row |x - dequant(quant(x))| <= absmax/127 for int8
    (half-ulp of the int8 grid), resp. absmax/16 for fp8 (e4m3 half-ulp is
    2^-4 relative) — the §5.2 compression is lossy but bounded.  Covers
    single-row payloads (rows=1) and all-zero rows, which must round-trip
    to exactly zero."""
    x = np.random.default_rng(rows * 100 + cols).normal(
        size=(rows, cols)).astype(np.float32) * scale
    if zero_row:
        x[rows // 2] = 0.0
    q, s = quantize_rows_ref(jnp.asarray(x), codec=codec)
    xr = np.asarray(dequantize_rows_ref(q, s, codec=codec))
    half_ulp = 0.5 / 127.0 if codec == "int8" else 1.0 / 16.0
    bound = np.abs(x).max(axis=1) * half_ulp + 1e-7
    err = np.abs(xr - x).max(axis=1)
    assert np.all(err <= bound * 1.01)
    if zero_row:
        assert np.all(xr[rows // 2] == 0.0)
