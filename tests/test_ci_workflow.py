"""CI contract tests: the committed workflow must keep gating the repo.

The acceptance criterion for the CI satellite work is mechanical:
``.github/workflows/ci.yml`` parses, the fast-tier job runs the ROADMAP
tier-1 command *verbatim*, the kernel leg pins interpret mode explicitly,
the nightly/dispatch leg runs ``--runslow``, and the smoke-benchmark job
schema-gates + uploads its artifact.  These tests pin that contract so a
workflow edit that silently weakens the gate fails the gate itself.

Also covers the benchmark artifact schema checker
(``benchmarks/check_artifact_schema.py``): the committed artifact matches
the committed schema, and injected drift (dropped or renamed keys) is
detected.
"""
import importlib.util
import json
import pathlib

import pytest

yaml = pytest.importorskip("yaml")

ROOT = pathlib.Path(__file__).resolve().parents[1]
WORKFLOW = ROOT / ".github" / "workflows" / "ci.yml"
TIER1 = "PYTHONPATH=src python -m pytest -x -q"


@pytest.fixture(scope="module")
def wf():
    return yaml.safe_load(WORKFLOW.read_text())


def _triggers(wf):
    # YAML 1.1 parses the bare key `on` as boolean True
    return wf.get("on", wf.get(True))


def _steps(job):
    return job["steps"]


def _run_lines(job):
    return [s["run"] for s in _steps(job) if "run" in s]


def test_workflow_parses_with_all_triggers(wf):
    trig = _triggers(wf)
    assert set(trig) >= {"push", "pull_request", "workflow_dispatch",
                         "schedule"}
    assert trig["schedule"], "nightly leg needs a cron schedule"
    assert set(wf["jobs"]) >= {"tests", "bench-smoke", "serve-smoke",
                               "serve-chaos", "lint", "nightly-slow",
                               "recovery-drill", "recovery-drill-tpu"}


def test_fast_tier_runs_tier1_command_verbatim(wf):
    legs = wf["jobs"]["tests"]["strategy"]["matrix"]["include"]
    by_tier = {leg["tier"]: leg for leg in legs}
    assert by_tier["fast"]["run"] == TIER1
    # the matrix command is what the job actually executes
    assert "${{ matrix.run }}" in _run_lines(wf["jobs"]["tests"])[-1]


def test_kernel_leg_sets_interpret_mode_explicitly(wf):
    legs = wf["jobs"]["tests"]["strategy"]["matrix"]["include"]
    by_tier = {leg["tier"]: leg for leg in legs}
    kernel_run = by_tier["kernels-interpret"]["run"]
    assert kernel_run.startswith("REPRO_PALLAS_INTERPRET=1 ")
    assert "tests/test_kernels.py" in kernel_run
    # the compressed-traversal-wire acceptance grid rides the same leg:
    # it drives the quantizer kernels end to end through the transport
    assert "tests/test_wire_compression.py" in kernel_run


def test_test_jobs_pin_cpu_backend_and_jax_wheel(wf):
    for name in ("tests", "bench-smoke", "serve-smoke", "serve-chaos",
                 "nightly-slow"):
        assert wf["jobs"][name]["env"]["JAX_PLATFORMS"] == "cpu", name
    # pip caching keyed on the pinned requirements file
    for name in ("tests", "bench-smoke", "serve-smoke", "serve-chaos",
                 "nightly-slow"):
        setup = [s for s in _steps(wf["jobs"][name])
                 if "setup-python" in s.get("uses", "")][0]
        assert setup["with"]["cache"] == "pip", name
        assert setup["with"]["cache-dependency-path"] == "requirements-ci.txt"
    reqs = (ROOT / "requirements-ci.txt").read_text()
    assert "jax==" in reqs and "jaxlib==" in reqs


def test_nightly_leg_is_gated_and_runs_slow_tests(wf):
    job = wf["jobs"]["nightly-slow"]
    assert "schedule" in job["if"] and "workflow_dispatch" in job["if"]
    assert any("--runslow" in r for r in _run_lines(job))
    # the fast gate must NOT creep into running slow depth tests
    assert all("--runslow" not in str(leg)
               for leg in wf["jobs"]["tests"]["strategy"]["matrix"]["include"])


def test_bench_smoke_job_gates_schema_and_uploads_artifact(wf):
    job = wf["jobs"]["bench-smoke"]
    runs = " ".join(_run_lines(job))
    assert "benchmarks/run.py --only tl_step_smoke" in runs
    assert "check_artifact_schema.py" in runs
    assert "benchmarks/schemas/tl_step_smoke.schema.json" in runs
    uploads = [s for s in _steps(job)
               if "upload-artifact" in s.get("uses", "")]
    assert uploads and uploads[0]["with"]["path"] == "BENCH_tl_step_smoke.json"


def test_bench_smoke_job_gates_hierarchy_schema_and_uploads_artifact(wf):
    """The two-tier hierarchy smoke (64 simulated nodes) rides the
    bench-smoke job: run, schema-gated, uploaded — like tl_step_smoke."""
    job = wf["jobs"]["bench-smoke"]
    runs = " ".join(_run_lines(job))
    assert "hierarchy_smoke" in runs
    assert "BENCH_hierarchy_smoke.json" in runs
    assert "benchmarks/schemas/hierarchy_smoke.schema.json" in runs
    uploads = [s for s in _steps(job)
               if "upload-artifact" in s.get("uses", "")]
    hier = [u for u in uploads
            if u["with"]["path"] == "BENCH_hierarchy_smoke.json"]
    assert hier and hier[0]["if"] == "always()"


def test_serve_smoke_job_gates_schema_and_uploads_artifact(wf):
    job = wf["jobs"]["serve-smoke"]
    runs = " ".join(_run_lines(job))
    assert "benchmarks/run.py --only serve_smoke" in runs
    assert "check_artifact_schema.py" in runs
    assert "benchmarks/schemas/serve_smoke.schema.json" in runs
    uploads = [s for s in _steps(job)
               if "upload-artifact" in s.get("uses", "")]
    assert uploads and uploads[0]["with"]["path"] == "BENCH_serve_smoke.json"
    assert uploads[0]["if"] == "always()"


def test_recovery_drill_job_verifies_the_elastic_guarantee(wf):
    """The recovery-drill job must (a) run the elastic drill on the
    forced-8 host mesh and pin the bit-equal verdict, and (b) prove the
    non-elastic flavor fails *loudly* — a specific exit code, so a
    timeout-killed silent hang can never pass."""
    job = wf["jobs"]["recovery-drill"]
    assert job["env"]["JAX_PLATFORMS"] == "cpu"
    assert "--xla_force_host_platform_device_count=8" in job["env"]["XLA_FLAGS"]
    runs = " ".join(_run_lines(job))
    assert "--mesh host --elastic" in runs
    assert "--drill kill-device:2" in runs
    assert "RECOVERY_DRILL bit_equal=true" in runs
    # the loud-failure leg: watchdog-classified hang, pinned exit code
    assert "hang-device:1" in runs and "--elastic" not in runs.split(
        "hang-device:1")[1]
    assert 'test "$code" -eq 2' in runs


def test_serve_chaos_job_verifies_token_identity_and_loud_failure(wf):
    """The serve-chaos job must (a) run the supervised hang+crash drill
    and pin the token-identity verdict, and (b) prove the unsupervised
    flavor fails *loudly* with the CLI's pinned exit code — a timeout kill
    (124) of a silently wedged engine can never pass."""
    job = wf["jobs"]["serve-chaos"]
    assert job["env"]["JAX_PLATFORMS"] == "cpu"
    runs = " ".join(_run_lines(job))
    assert "repro.launch.serve" in runs
    assert "--engine continuous" in runs
    assert "--chaos hang:3,crash:6" in runs
    assert "SERVE_DRILL token_identical=true" in runs
    # the loud-failure leg: watchdog-classified hang, pinned exit code
    tail = runs.split("--no-supervise")
    assert len(tail) == 2 and "--chaos hang:1" in tail[0]
    assert 'test "$code" -eq 2' in runs


def test_recovery_drill_tpu_stub_is_dispatch_only(wf):
    job = wf["jobs"]["recovery-drill-tpu"]
    assert job["if"] == "github.event_name == 'workflow_dispatch'"
    assert any("repro.launch.train" in r for r in _run_lines(job))


def test_lint_job_runs_ruff_with_committed_config(wf):
    runs = " ".join(_run_lines(wf["jobs"]["lint"]))
    assert "ruff check" in runs
    cfg = (ROOT / "pyproject.toml").read_text()
    assert "[tool.ruff" in cfg and "F401" in cfg


def test_readme_documents_tiers_and_badge():
    readme = (ROOT / "README.md").read_text()
    assert "actions/workflows/ci.yml/badge.svg" in readme
    assert TIER1 in readme                       # local repro command
    assert "--runslow" in readme
    assert "REPRO_PALLAS_INTERPRET=1" in readme


# ------------------------------------------------ artifact schema checker
def _checker():
    spec = importlib.util.spec_from_file_location(
        "check_artifact_schema",
        ROOT / "benchmarks" / "check_artifact_schema.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_committed_artifact_matches_committed_schema():
    mod = _checker()
    rc = mod.main([str(ROOT / "BENCH_tl_step_smoke.json"),
                   "--schema",
                   str(ROOT / "benchmarks" / "schemas"
                       / "tl_step_smoke.schema.json")])
    assert rc == 0


def test_committed_serve_artifact_matches_committed_schema():
    """The serve-smoke CI gate, run locally: the committed artifact and
    schema agree, and numeric offered-load keys are wildcarded so changing
    the load grid is not drift."""
    mod = _checker()
    schema = str(ROOT / "benchmarks" / "schemas" / "serve_smoke.schema.json")
    assert mod.main([str(ROOT / "BENCH_serve_smoke.json"),
                     "--schema", schema]) == 0
    art = json.loads((ROOT / "BENCH_serve_smoke.json").read_text())
    loads = art["result"]["archs"]["deepseek-7b"]["loads"]
    loads["64.0"] = next(iter(loads.values()))    # extra load point: fine
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        p = pathlib.Path(d) / "sweep.json"
        p.write_text(json.dumps(art))
        assert mod.main([str(p), "--schema", schema]) == 0
        broken = json.loads((ROOT / "BENCH_serve_smoke.json").read_text())
        for point in broken["result"]["archs"]["deepseek-7b"][
                "loads"].values():
            point.pop("p99_token_latency_ms")     # dropped metric: drift
        p.write_text(json.dumps(broken))
        assert mod.main([str(p), "--schema", schema]) == 1


def test_schema_drift_is_detected(tmp_path):
    mod = _checker()
    artifact = json.loads((ROOT / "BENCH_tl_step_smoke.json").read_text())
    schema = str(ROOT / "benchmarks" / "schemas"
                 / "tl_step_smoke.schema.json")

    dropped = dict(artifact)
    dropped["result"] = {k: v for k, v in artifact["result"].items()
                         if k != "backend"}
    p1 = tmp_path / "dropped.json"
    p1.write_text(json.dumps(dropped))
    assert mod.main([str(p1), "--schema", schema]) == 1

    renamed = json.loads(json.dumps(artifact))
    renamed["result"]["nodes"]["2"]["speedup_x"] = \
        renamed["result"]["nodes"]["2"].pop("speedup")
    p2 = tmp_path / "renamed.json"
    p2.write_text(json.dumps(renamed))
    assert mod.main([str(p2), "--schema", schema]) == 1


def test_schema_wildcards_node_counts(tmp_path):
    """A full-sweep artifact with extra node counts has the SAME schema —
    numeric table keys are wildcarded, so sweeping 2/4/8 nodes instead of 2
    is not drift."""
    mod = _checker()
    artifact = json.loads((ROOT / "BENCH_tl_step_smoke.json").read_text())
    artifact["result"]["nodes"]["4"] = artifact["result"]["nodes"]["2"]
    artifact["result"]["nodes"]["8"] = artifact["result"]["nodes"]["2"]
    p = tmp_path / "sweep.json"
    p.write_text(json.dumps(artifact))
    schema = str(ROOT / "benchmarks" / "schemas"
                 / "tl_step_smoke.schema.json")
    assert mod.main([str(p), "--schema", schema]) == 0


def test_schema_write_roundtrip(tmp_path):
    mod = _checker()
    out = tmp_path / "schema.json"
    art = str(ROOT / "BENCH_tl_step_smoke.json")
    assert mod.main([art, "--schema", str(out), "--write"]) == 0
    assert mod.main([art, "--schema", str(out)]) == 0
    committed = json.loads(
        (ROOT / "benchmarks" / "schemas"
         / "tl_step_smoke.schema.json").read_text())
    assert json.loads(out.read_text())["paths"] == committed["paths"]
