"""Compressed traversal wire: acceptance grid for the WirePolicy lanes.

The tentpole claims, each pinned here against the real protocol paths:

* **Bandwidth**: int8 on the visit-payload tag cuts X^(1)/δ^(L)/∂X^(1)/
  ∂W^(1) wire bytes ≥3.5× at an unchanged visit plan, with model-parameter
  bytes unchanged — measured from ``Transport.raw_bytes`` / ``bytes_sent``
  and the per-send ``wire:*`` WindowRecords, not estimated.
* **Wire off is free**: a policy that doesn't cover the visit tag leaves
  the run bit-equal to a policy-less transport (the lossless grid in
  ``test_tl_lossless.py`` is untouched).
* **Lossless in the limit**: error-feedback training on the
  {fused, eager} × {2, 3 uneven nodes} grid converges to within tolerance
  of the uncompressed run over multiple epochs.
* **Faults compose**: a dropped-then-retried attempt charges exactly the
  compressed payload bytes in ``fault_log``, and the EF residual is
  suspended across the drop so the retry ships a byte-identical payload —
  the faulty run ends bit-equal to the fault-free compressed run.
* **Eq. 19 alignment**: ``runtime_model.runtime_tl(compressed=...)``
  predicts the transport's measured bytes/clock exactly (modulo the
  8 B/batch protocol scalars the analytic model doesn't carry).
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import DATRET
from repro.core.faults import FaultInjector, FaultSpec, RecoveryPolicy
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.runtime_model import WorkloadSpec, runtime_tl
from repro.core.transport import (LaneSpec, NetworkModel, Transport,
                                  WirePolicy, payload_bytes)
from repro.models.small import SmallModel
from repro.optim import sgd

INT8 = WirePolicy.visits("int8")
INT8_EF = WirePolicy.visits("int8", error_feedback=True)
FP8_EF = WirePolicy.visits("fp8", error_feedback=True)


def _build(sizes, *, wire=None, fused=True, fault=None, pipelined=False,
           batch=16, seed=7, network=None, cache_model=False):
    model = SmallModel(DATRET)
    r = np.random.default_rng(seed)
    data = [(r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
             r.integers(0, DATRET.n_classes, n)) for n in sizes]
    nodes = [TLNode(i, model, x, y, jit_visits=fused)
             for i, (x, y) in enumerate(data)]
    tr = Transport(network=network or NetworkModel(), wire=wire,
                   faults=FaultInjector(fault) if fault else None)
    orch = TLOrchestrator(model, nodes, sgd(0.05), tr, batch_size=batch,
                          plan=PlanSpec(seed=0,
                                        recovery=RecoveryPolicy(backoff_s=0.0)),
                          fused=fused, pipelined=pipelined,
                          cache_model_per_epoch=cache_model)
    orch.initialize(jax.random.PRNGKey(3))
    return orch


def _assert_bitequal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------- bandwidth win

def test_int8_wire_cuts_visit_bytes_3_5x_with_model_bytes_unchanged():
    off = _build([32, 32])
    comp = _build([32, 32], wire=INT8)
    off.train_epoch()
    comp.train_epoch()
    tag = "activations_grads"
    # unchanged visit plan: the compressed run pushed the same raw payloads
    # (shapes are plan-determined, not value-determined)
    assert comp.transport.raw_bytes[tag] == off.transport.bytes_sent[tag]
    ratio = comp.transport.raw_bytes[tag] / comp.transport.bytes_sent[tag]
    assert ratio >= 3.5
    # model redistribution ships exact, byte-for-byte as before
    assert (comp.transport.bytes_sent["model"]
            == off.transport.bytes_sent["model"]
            == comp.transport.raw_bytes["model"])
    # the win is measured per send in window_log, and the records sum back
    # to the tag counters exactly
    recs = [r for r in comp.transport.window_log if r.kind == "wire:int8"]
    assert recs and all(r.meta["ratio"] >= 3.5 for r in recs)
    assert sum(r.nbytes for r in recs) == comp.transport.bytes_sent[tag]
    assert (sum(r.meta["raw_bytes"] for r in recs)
            == comp.transport.raw_bytes[tag])
    assert not [r for r in off.transport.window_log
                if r.kind.startswith("wire:")]


def test_wire_off_keeps_the_run_bit_equal():
    """A policy that doesn't cover the visit tag is indistinguishable from
    no policy: same bytes, same clock, bit-equal parameters — the lossless
    acceptance grid needs no wire-off re-run."""
    plain = _build([24, 16])
    offpol = _build([24, 16],
                    wire=WirePolicy({"unused_tag": LaneSpec("int8")}))
    s1 = [s for _ in range(2) for s in plain.train_epoch()]
    s2 = [s for _ in range(2) for s in offpol.train_epoch()]
    _assert_bitequal(plain.params, offpol.params)
    np.testing.assert_array_equal([s.loss for s in s1], [s.loss for s in s2])
    assert plain.transport.bytes_sent == offpol.transport.bytes_sent
    assert plain.transport.clock_s == offpol.transport.clock_s


# ------------------------------------------------------------ EF convergence

@pytest.mark.parametrize("sizes", [[32, 32], [40, 24, 16]],
                         ids=["2nodes", "3nodes-uneven"])
@pytest.mark.parametrize("fused", [True, False], ids=["fused", "eager"])
def test_ef_training_converges_with_uncompressed(sizes, fused):
    """EF-compressed training tracks the uncompressed run over multiple
    epochs: the loss comes down and ends within tolerance of the exact
    run's final loss — biased-lossy would drift, error feedback must not."""
    base = _build(sizes, fused=fused)
    ef = _build(sizes, fused=fused, wire=INT8_EF)
    base_stats = [s for _ in range(4) for s in base.train_epoch()]
    ef_stats = [s for _ in range(4) for s in ef.train_epoch()]
    b0 = np.mean([s.loss for s in base_stats[:3]])
    b1 = np.mean([s.loss for s in base_stats[-3:]])
    e1 = np.mean([s.loss for s in ef_stats[-3:]])
    assert b1 < b0, "uncompressed baseline failed to train"
    assert abs(e1 - b1) < 0.05 * max(b1, 1e-3) + 5e-3


def test_fp8_ef_training_converges():
    base = _build([32, 32])
    ef = _build([32, 32], wire=FP8_EF)
    base_stats = [s for _ in range(4) for s in base.train_epoch()]
    ef_stats = [s for _ in range(4) for s in ef.train_epoch()]
    b1 = np.mean([s.loss for s in base_stats[-3:]])
    e1 = np.mean([s.loss for s in ef_stats[-3:]])
    assert abs(e1 - b1) < 0.10 * max(b1, 1e-3) + 1e-2


def test_pipelined_equals_serial_under_ef_compression():
    """The pipelined producer routes through the same ``_collect_visits``
    in the same Python order, so the EF residual sequence — and therefore
    every parameter bit — matches the serial run."""
    serial = _build([24, 16], wire=INT8_EF, pipelined=False)
    piped = _build([24, 16], wire=INT8_EF, pipelined=True)
    s1 = [s for _ in range(2) for s in serial.train_epoch()]
    s2 = [s for _ in range(2) for s in piped.train_epoch()]
    _assert_bitequal(serial.params, piped.params)
    np.testing.assert_array_equal([s.loss for s in s1], [s.loss for s in s2])


# ------------------------------------------------------- faults × compression

def test_drop_charges_exactly_the_compressed_attempt_bytes():
    """Transport-level drill: every dropped attempt charges exactly one
    compressed payload (q + scales, not the raw f32 bytes) to fault_log,
    and the EF residual is suspended across drops — the delivered payload
    and the post-send residual are bit-equal to a fault-free transport's."""
    x = {"acts": jax.random.normal(jax.random.PRNGKey(9), (64, 128))}
    pol = WirePolicy({"t": LaneSpec("int8", error_feedback=True)})
    clean = Transport(wire=pol)
    want = clean.send("t", x, compressible=True, key=0)

    from repro.core.faults import VisitDropped
    tr = Transport(wire=pol,
                   faults=FaultInjector(FaultSpec(drop_prob=0.6, seed=5)))
    attempts = 0
    while True:
        try:
            with tr.fault_lane((0, 0, 0, attempts)):
                got = tr.send("t", x, compressible=True, key=0)
            break
        except VisitDropped:
            attempts += 1
    assert attempts >= 1
    one = clean.bytes_sent["t"]
    assert one < payload_bytes(x) / 3.5
    # every attempt (dropped or delivered) charged exactly one compressed
    # payload; fault_log carries the compressed size, not the raw size
    assert tr.bytes_sent["t"] == (attempts + 1) * one
    assert all(ev.nbytes == one for ev in tr.fault_log)
    assert tr.raw_bytes["t"] == (attempts + 1) * payload_bytes(x)
    # EF suspension: the delivered payload and the residual state match the
    # fault-free transport bit-for-bit
    _assert_bitequal(got, want)
    _assert_bitequal(tr._ef_residuals[(0, "t", 0)],
                     clean._ef_residuals[(0, "t", 0)])
    # and the *next* send (residual now live) still matches
    _assert_bitequal(tr.send("t", x, compressible=True, key=0),
                     clean.send("t", x, compressible=True, key=0))


def test_faulty_ef_run_is_bit_equal_to_fault_free_compressed_run():
    """End-to-end drill: drops + retries under int8+EF leave parameters
    bit-equal to the fault-free compressed run, and total visit bytes equal
    the fault-free bytes plus exactly the dropped attempts' compressed
    bytes (the fault-accounting invariant, now under compression)."""
    clean = _build([20, 12], wire=INT8_EF)
    faulty = _build([20, 12], wire=INT8_EF,
                    fault=FaultSpec(drop_prob=0.4, seed=11))
    s1 = [s for _ in range(2) for s in clean.train_epoch()]
    s2 = [s for _ in range(2) for s in faulty.train_epoch()]
    _assert_bitequal(clean.params, faulty.params)
    np.testing.assert_array_equal([s.loss for s in s1], [s.loss for s in s2])
    tag = "activations_grads"
    drops = [r for r in faulty.transport.window_log if r.kind == "fault:drop"]
    assert drops, "the injector never fired — the drill tested nothing"
    assert (faulty.transport.bytes_sent[tag]
            == clean.transport.bytes_sent[tag]
            + sum(r.by_tag.get(tag, 0) for r in drops))
    # dropped attempts were charged at the compressed size
    raw_per_wire = (faulty.transport.raw_bytes[tag]
                    / faulty.transport.bytes_sent[tag])
    assert raw_per_wire >= 3.5


# ------------------------------------------------- eq. 19 predicted vs. real

def _measured_run(wire):
    net = NetworkModel(bandwidth_bytes_per_s=1e6, rtt_s=0.0)
    orch = _build([64], wire=wire, batch=32, network=net)
    orch.train_epoch()
    return orch


def test_runtime_tl_bytes_and_clock_match_transport_measurement():
    """One-node serial epoch with rtt=0 and zero compute time: eq. 19's
    byte term must reproduce ``Transport``'s measured bytes *exactly*,
    modulo the 8 B/batch loss_sum/n_correct scalars the analytic model
    doesn't carry — for both the raw and the compressed wire (the
    satellite fix: 1 B/element + 4 B/row, matching ``compressed_bytes``)."""
    off = _measured_run(None)
    comp = _measured_run(INT8)
    bw = 1e6
    n_batches = 2
    model_bytes = payload_bytes(off.params)
    spec = WorkloadSpec(
        n_nodes=1, samples_per_node=64, batch_size=32,
        model_bytes=model_bytes,
        first_layer_bytes_per_sample=DATRET.hidden[0] * 4,        # X^(1) row
        logits_bytes_per_sample=DATRET.n_classes * 4,             # δ^(L) row
        first_layer_param_bytes=(DATRET.in_shape[0] + 1)
        * DATRET.hidden[0] * 4,                                   # W^(1) + b
        flops_per_sample_fwd=0.0, flops_per_sample_bwd=0.0,
        bandwidth_bytes_per_s=bw, rtt_s=0.0)
    scalars = 8 * n_batches                  # loss_sum f32 + n_correct i32
    for orch, compressed in ((off, False), (comp, True)):
        tr = orch.transport
        predicted = runtime_tl(spec, compressed=compressed,
                               pipelined=False) * bw
        measured = (tr.bytes_sent["activations_grads"]
                    + tr.bytes_sent["model"])
        assert measured == round(predicted) + scalars
        # rtt=0 ⇒ the serial clock is exactly bytes / bandwidth
        assert abs(tr.clock_s * bw - tr.total_bytes) < 1e-3
        assert abs(tr.clock_s - runtime_tl(spec, compressed=compressed,
                                           pipelined=False)
                   - scalars / bw) < 1e-6
