"""The fused jitted orchestrator step is numerically identical to the eager
reference TL path — the lossless guarantee survives the optimization.

Fused path: jitted node visits (device-resident stats, pruned gw1), one
batched scatter reassembly, one compiled vjp+eq.12+update step with donated
params/opt_state.  Eager path: the seed's op-by-op reference.  Both must
produce the same parameter trajectory to within a few float32 ULPs (the only
difference XLA fusion is permitted to introduce) over multiple steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import CONVNET, DATRET
from repro.core.node import TLNode, first_layer_grad_leaves
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.models.small import SmallModel
from repro.optim import sgd

# a handful of float32 ULPs at the parameters' magnitude: what jit fusion
# may legitimately reorder, and nothing more
ULP_FACTOR = 16


def _make_nodes(model, cfg, sizes, seed, jit_visits):
    r = np.random.default_rng(seed)
    nodes = []
    for i, n in enumerate(sizes):
        if cfg.family == "transformer":
            x = r.integers(0, cfg.vocab_size, (n, cfg.seq_len))
        else:
            x = r.normal(size=(n,) + cfg.in_shape).astype(np.float32)
        y = r.integers(0, cfg.n_classes, n)
        nodes.append(TLNode(i, model, x, y, jit_visits=jit_visits))
    return nodes


@pytest.mark.parametrize("reassembly", ["xla", "pallas"])
@pytest.mark.parametrize("cfg", [DATRET, CONVNET], ids=lambda c: c.name)
def test_fused_step_matches_eager_reference(cfg, reassembly):
    model = SmallModel(cfg)
    sizes = [13, 8, 11, 9]                                  # 4-node split
    eager = TLOrchestrator(model, _make_nodes(model, cfg, sizes, 7, False),
                           sgd(0.05), Transport(), batch_size=16,
                           plan=PlanSpec(seed=0), fused=False)
    fused = TLOrchestrator(model, _make_nodes(model, cfg, sizes, 7, True),
                           sgd(0.05), Transport(), batch_size=16,
                           plan=PlanSpec(seed=0), fused=True, donate=True,
                           reassembly=reassembly)
    key = jax.random.PRNGKey(3)
    eager.initialize(key)
    fused.initialize(key)

    n_steps = 0
    for _ in range(2):                                      # >= 3 TL steps
        se = eager.train_epoch()
        sf = fused.train_epoch()
        n_steps += len(se)
        for a, b in zip(se, sf):
            assert abs(a.loss - float(b.loss)) < 1e-6
            assert abs(a.acc - float(b.acc)) < 1e-9
            assert float(b.grad_consistency) < 1e-5         # eq. 12 holds
    assert n_steps >= 3

    eps = np.finfo(np.float32).eps
    for pa, pb in zip(jax.tree.leaves(eager.params),
                      jax.tree.leaves(fused.params)):
        a = np.asarray(pa, dtype=np.float64)
        b = np.asarray(pb, dtype=np.float64)
        tol = ULP_FACTOR * eps * max(1.0, float(np.abs(a).max()))
        assert np.abs(a - b).max() <= tol, \
            f"fused update drifted {np.abs(a - b).max():.3e} > {tol:.3e}"


@pytest.mark.parametrize("sizes", [[20, 12], [13, 8, 11]],
                         ids=["2nodes-uneven", "3nodes-uneven"])
@pytest.mark.parametrize("cache", [False, True], ids=["strict", "cached"])
def test_pallas_reassembly_matches_xla_scatter(sizes, cache):
    """Acceptance grid: the ``reassembly="pallas"`` fused step tracks the
    XLA-scatter path to float32 ULP across {2,3 uneven nodes} × {model
    cache on/off} — same stats per step, same parameter trajectory.  (In
    practice the reassembled values are bit-identical; only downstream jit
    fusion choices may differ.)"""
    cfg = DATRET
    model = SmallModel(cfg)

    def build(reassembly):
        orch = TLOrchestrator(model, _make_nodes(model, cfg, sizes, 5, True),
                              sgd(0.05), Transport(), batch_size=16,
                              plan=PlanSpec(seed=0),
                              fused=True, donate=not cache,
                              cache_model_per_epoch=cache,
                              reassembly=reassembly)
        orch.initialize(jax.random.PRNGKey(1))
        return orch

    xla, pallas = build("xla"), build("pallas")
    for _ in range(3):
        sx = xla.train_epoch()
        sp = pallas.train_epoch()
        assert len(sx) == len(sp) >= 1
        for a, b in zip(sx, sp):
            assert abs(a.loss - b.loss) < 1e-6
            assert abs(a.acc - b.acc) < 1e-9
            assert abs(a.grad_consistency - b.grad_consistency) < 1e-6
            if not cache:
                assert b.grad_consistency < 1e-5            # eq. 12 holds

    eps = np.finfo(np.float32).eps
    for pa, pb in zip(jax.tree.leaves(xla.params),
                      jax.tree.leaves(pallas.params)):
        a = np.asarray(pa, dtype=np.float64)
        b = np.asarray(pb, dtype=np.float64)
        tol = ULP_FACTOR * eps * max(1.0, float(np.abs(a).max()))
        assert np.abs(a - b).max() <= tol, \
            f"pallas reassembly drifted {np.abs(a - b).max():.3e} > {tol:.3e}"


def test_fused_reuses_one_compiled_step(rng):
    """The fused centralized-BP step is compiled once and reused across
    virtual batches (same (N, shapes) signature)."""
    cfg = DATRET
    model = SmallModel(cfg)
    orch = TLOrchestrator(model, _make_nodes(model, cfg, [16, 16, 16, 16],
                                             11, True),
                          sgd(0.05), Transport(), batch_size=16,
                          plan=PlanSpec(seed=0))
    orch.initialize(jax.random.PRNGKey(0))
    orch.train_epoch()
    step = orch._fused_step
    assert step is not None
    orch.train_epoch()
    assert orch._fused_step is step                         # cached, not rebuilt


def test_first_layer_grad_leaves_are_minimal_and_sufficient(rng):
    """Structural pruning: the traced leaf set contains exactly the leaves
    with nonzero first-layer weight gradients."""
    cfg = DATRET
    model = SmallModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(4,) + cfg.in_shape).astype(np.float32))
    keep = first_layer_grad_leaves(model, params, x)

    _, pull = jax.vjp(lambda p: model.first_layer(p, x), params)
    (gw1,) = pull(jnp.ones_like(model.first_layer(params, x)))
    flat = jax.tree_util.tree_leaves(gw1)
    nonzero = {i for i, g in enumerate(flat) if float(jnp.abs(g).max()) > 0}
    assert nonzero <= set(keep)            # every populated leaf is kept
    # and the kept set is tight: for the MLP only layer-0's (w, b) survive
    assert len(keep) == 2
