"""Paper §3.4 (async buffered TL) and §5.1 (partial parameter transfer)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import DATRET
from repro.core.async_tl import (GradientBuffer, BufferedContribution,
                                 LatencyTracker, async_train_epoch)
from repro.core.baselines import ShardData, evaluate, train_cl
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.partial_update import PartialUpdateCodec
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.data.datasets import shard_iid, tabular
from repro.models.small import SmallModel
from repro.optim import sgd


@pytest.fixture(scope="module")
def setup():
    ds = tabular(400, 32, 4, seed=0, margin=2.0, noise=0.8)
    train, test = ds.split(0.8, seed=0)
    shards = shard_iid(train, 4, seed=0)
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    return model, shards, test


def test_gradient_buffer_drops_stale():
    buf = GradientBuffer(min_contributions=2, max_staleness=1)
    g = {"w": jnp.ones(3)}
    buf.add(BufferedContribution(0, model_version=0, grads=g, loss_sum=1.0,
                                 n_samples=4), current_version=0)
    buf.add(BufferedContribution(1, model_version=0, grads=g, loss_sum=1.0,
                                 n_samples=4), current_version=5)   # stale
    assert buf.n_dropped_stale == 1
    assert not buf.ready()


def test_gradient_buffer_staleness_boundary():
    """Dropped exactly when version - model_version > max_staleness: the
    boundary case (== max_staleness) is kept."""
    buf = GradientBuffer(min_contributions=1, max_staleness=2)
    g = {"w": jnp.ones(3)}

    def contrib(model_version):
        return BufferedContribution(0, model_version=model_version, grads=g,
                                    loss_sum=1.0, n_samples=4)

    buf.add(contrib(0), current_version=2)      # staleness == 2: kept
    assert len(buf._items) == 1 and buf.n_dropped_stale == 0
    buf.add(contrib(0), current_version=3)      # staleness == 3: dropped
    assert len(buf._items) == 1 and buf.n_dropped_stale == 1


def test_gradient_buffer_drain_empties():
    buf = GradientBuffer(min_contributions=2)
    g = {"w": jnp.ones(3)}
    for nid in range(2):
        buf.add(BufferedContribution(nid, model_version=0, grads=g,
                                     loss_sum=1.0, n_samples=4),
                current_version=0)
    assert buf.ready()
    grads, loss, n = buf.drain()
    assert n == 8 and abs(loss - 2.0) < 1e-9
    np.testing.assert_allclose(np.asarray(grads["w"]), 2 * np.ones(3))
    # drained: empty, not ready, and a second drain is a well-defined no-op
    assert buf._items == [] and not buf.ready()
    assert buf.drain() == (None, 0.0, 0)


def test_gradient_buffer_flush_equals_exactly_full():
    """The end-of-batch flush path (drain before min_contributions) applies
    the same combination as a buffer that became exactly full."""
    key = jax.random.PRNGKey(0)
    contribs = [
        BufferedContribution(i, model_version=0,
                             grads={"w": jax.random.normal(
                                 jax.random.fold_in(key, i), (5,))},
                             loss_sum=0.5 * (i + 1), n_samples=3 + i)
        for i in range(2)]
    full = GradientBuffer(min_contributions=2)          # becomes ready
    flush = GradientBuffer(min_contributions=5)         # drained by flush
    for c in contribs:
        full.add(c, current_version=0)
        flush.add(c, current_version=0)
    assert full.ready() and not flush.ready()
    gf, lf, nf = full.drain()
    gx, lx, nx = flush.drain()
    assert (lf, nf) == (lx, nx)
    np.testing.assert_array_equal(np.asarray(gf["w"]), np.asarray(gx["w"]))


def test_async_flush_epoch_matches_exactly_full_epoch(setup):
    """Epoch-level: min_contributions larger than any batch's node count
    forces every update through the flush path; the parameter trajectory is
    identical to the exactly-full (min_contributions=None) run."""
    model, shards, test = setup
    key = jax.random.PRNGKey(4)
    params = []
    for min_c in (None, 100):
        nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
        orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                              batch_size=32, plan=PlanSpec(seed=0),
                              check_consistency=False)
        orch.initialize(key)
        stats, _ = async_train_epoch(orch, min_contributions=min_c)
        assert stats                               # updates were applied
        params.append(orch.params)
    for a, b in zip(jax.tree.leaves(params[0]), jax.tree.leaves(params[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_uses_cached_contrib_step_on_fused_orch(setup):
    """§3.4 integration: on a fused orchestrator the per-contribution BP
    goes through the cached jitted step (built once), not an eager vjp."""
    model, shards, test = setup
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=32, plan=PlanSpec(seed=0),
                          check_consistency=False)
    orch.initialize(jax.random.PRNGKey(0))
    assert orch._contrib_step is None
    async_train_epoch(orch)
    step = orch._contrib_step
    assert step is not None
    async_train_epoch(orch)
    assert orch._contrib_step is step              # cached, not rebuilt


def test_latency_tracker_orders_fast_first():
    t = LatencyTracker()
    t.observe(0, 1.0)
    t.observe(1, 0.1)
    t.observe(2, 0.5)
    assert t.priority_order([0, 1, 2]) == [1, 2, 0]


def test_async_epoch_trains(setup):
    model, shards, test = setup
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=32, plan=PlanSpec(seed=0),
                          check_consistency=False)
    orch.initialize(jax.random.PRNGKey(0))
    lat = {0: 0.01, 1: 0.5, 2: 0.02, 3: 0.05}
    for _ in range(3):
        stats, tracker = async_train_epoch(
            orch, min_contributions=2, max_staleness=2,
            node_latency_fn=lambda n: lat[n])
    acc = evaluate(model, orch.params, test.x, test.y)["acc"]
    assert acc > 0.6
    # the tracker learned node 1 is slowest
    assert tracker.priority_order([0, 1, 2, 3])[-1] == 1


def test_async_with_full_contributions_matches_sync_quality(setup):
    """min_contributions == all nodes per batch ≈ strict TL."""
    model, shards, test = setup
    key = jax.random.PRNGKey(1)
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=32, plan=PlanSpec(seed=0),
                          check_consistency=False)
    orch.initialize(key)
    for _ in range(3):
        async_train_epoch(orch)
    acc_async = evaluate(model, orch.params, test.x, test.y)["acc"]
    sdata = [ShardData(s.x, s.y) for s in
             [ShardData(jnp.asarray(sh.x), jnp.asarray(sh.y)) for sh in shards]]
    p_cl = train_cl(model, sdata, sgd(0.05), key=key, epochs=3, batch_size=32)
    acc_cl = evaluate(model, p_cl, test.x, test.y)["acc"]
    assert abs(acc_async - acc_cl) < 0.15


# ------------------------------------------------------------- §5.1 partial

def test_partial_update_roundtrip_threshold():
    key = jax.random.PRNGKey(0)
    old = {"a": jax.random.normal(key, (32, 16)), "b": jnp.zeros((8,))}
    new = jax.tree.map(lambda x: x + 0.5, old)
    codec = PartialUpdateCodec(threshold=0.0)
    payload = codec.encode(old, new)
    patched = PartialUpdateCodec.apply(old, payload)
    for a, b in zip(jax.tree.leaves(patched), jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_partial_update_residual_bounds_drift():
    """Un-shipped deltas accumulate and ship later; drift <= threshold."""
    key = jax.random.PRNGKey(1)
    p0 = {"w": jax.random.normal(key, (64,))}
    codec = PartialUpdateCodec(threshold=0.05)
    cached = p0
    true = p0
    for step in range(5):
        delta = 0.02 * jax.random.normal(jax.random.fold_in(key, step), (64,))
        new = {"w": true["w"] + delta}
        payload = codec.encode(true, new)
        cached = PartialUpdateCodec.apply(cached, payload)
        true = new
    drift = float(jnp.abs(cached["w"] - true["w"]).max())
    assert drift <= 0.05 + 1e-6
    assert codec.compression_ratio > 1.0


def test_partial_update_topk_compresses():
    key = jax.random.PRNGKey(2)
    old = {"w": jnp.zeros((1000,))}
    new = {"w": jax.random.normal(key, (1000,))}
    codec = PartialUpdateCodec(top_frac=0.1)
    codec.encode(old, new)
    assert codec.compression_ratio > 2.0
