"""Algorithm 1 properties: coverage, permutation, traversal-plan coherence.

Property-based via hypothesis: for any node population, every virtual batch
must (a) reference only valid (node, local) pairs, (b) cover each global id
at most once per epoch, (c) order traversal segments by first appearance,
(d) partition the batch's positions exactly.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.virtual_batch import (IndexRange, create_virtual_batches,
                                      global_reindex, make_traversal)


@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=8),
       batch=st.integers(1, 32), seed=st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_plan_properties(sizes, batch, seed):
    ranges = [IndexRange(i, n) for i, n in enumerate(sizes)]
    total = sum(sizes)
    plan = create_virtual_batches(ranges, min(batch, total), seed=seed)
    seen = set()
    for vb in plan.batches:
        positions = np.concatenate([s.batch_positions for s in vb.traversal])
        # traversal partitions the batch positions exactly
        assert sorted(positions.tolist()) == list(range(vb.size))
        # each node visited at most once per batch
        node_ids = [s.node_id for s in vb.traversal]
        assert len(node_ids) == len(set(node_ids))
        for seg in vb.traversal:
            n = sizes[seg.node_id]
            assert np.all(seg.local_indices >= 0)
            assert np.all(seg.local_indices < n)
            # the segment's rows really belong to that node
            gids = vb.global_ids[seg.batch_positions]
            assert np.all(plan.global_to_node[gids] == seg.node_id)
            assert np.array_equal(plan.global_to_local[gids],
                                  seg.local_indices)
        for g in vb.global_ids:
            assert g not in seen   # no duplicate sample within an epoch
            seen.add(int(g))
    # with drop_remainder, every complete batch is covered
    n_batches = total // min(batch, total)
    assert len(plan.batches) == n_batches


def test_global_reindex_bijection():
    ranges = [IndexRange(0, 10), IndexRange(1, 5), IndexRange(2, 7)]
    node_of, local_of = global_reindex(ranges)
    pairs = set(zip(node_of.tolist(), local_of.tolist()))
    assert len(pairs) == 22
    # randomized ids (§5.3) preserve the bijection
    node_r, local_r = global_reindex(ranges, randomize_ids=True, seed=3)
    assert set(zip(node_r.tolist(), local_r.tolist())) == pairs


def test_traversal_first_appearance_order():
    node_of = np.array([0, 0, 1, 1, 2, 2])
    local_of = np.array([0, 1, 0, 1, 0, 1])
    gids = np.array([4, 0, 5, 2])      # first appearance: node2, node0, node1
    segs = make_traversal(gids, node_of, local_of)
    assert [s.node_id for s in segs] == [2, 0, 1]


def test_shuffling_differs_across_epochs():
    ranges = [IndexRange(0, 64)]
    p0 = create_virtual_batches(ranges, 16, seed=0)
    p1 = create_virtual_batches(ranges, 16, seed=1)
    assert not np.array_equal(p0.batches[0].global_ids,
                              p1.batches[0].global_ids)


@given(sizes=st.lists(st.integers(1, 23), min_size=1, max_size=6),
       batch=st.integers(1, 17), seed=st.integers(0, 4))
@settings(max_examples=40, deadline=None)
def test_every_global_index_scattered_exactly_once(sizes, batch, seed):
    """Losslessness precondition for the scatter reassembly: without
    drop_remainder, every global index lands in exactly one batch position
    across the epoch, each batch's segments partition its positions, and
    the plan is a pure function of the seed — including batch sizes that
    don't divide N and single-sample nodes (min node size 1 above)."""
    ranges = [IndexRange(i, n) for i, n in enumerate(sizes)]
    total = sum(sizes)
    plan = create_virtual_batches(ranges, batch, seed=seed,
                                  drop_remainder=False)
    covered = []
    for vb in plan.batches:
        # segments partition this batch's positions exactly
        positions = np.concatenate([s.batch_positions for s in vb.traversal])
        assert sorted(positions.tolist()) == list(range(vb.size))
        # ... and map those positions back to the batch's global ids
        gids_via_segs = set()
        for seg in vb.traversal:
            gids_via_segs.update(vb.global_ids[seg.batch_positions].tolist())
        assert gids_via_segs == set(vb.global_ids.tolist())
        covered.extend(vb.global_ids.tolist())
    # exactly-once coverage of every global index across all batches
    assert sorted(covered) == list(range(total))
    # tail batch present iff batch doesn't divide N
    assert len(plan.batches) == -(-total // batch)

    # seed determinism: same seed -> identical plan, field by field
    plan2 = create_virtual_batches(ranges, batch, seed=seed,
                                   drop_remainder=False)
    assert np.array_equal(plan.global_to_node, plan2.global_to_node)
    assert np.array_equal(plan.global_to_local, plan2.global_to_local)
    for vb, vb2 in zip(plan.batches, plan2.batches):
        assert np.array_equal(vb.global_ids, vb2.global_ids)
        assert len(vb.traversal) == len(vb2.traversal)
        for s, s2 in zip(vb.traversal, vb2.traversal):
            assert s.node_id == s2.node_id
            assert np.array_equal(s.local_indices, s2.local_indices)
            assert np.array_equal(s.batch_positions, s2.batch_positions)


def test_single_sample_nodes_and_ragged_tail():
    """Deterministic pin of the awkward corner: several 1-sample nodes and a
    batch size that divides nothing."""
    ranges = [IndexRange(0, 1), IndexRange(1, 1), IndexRange(2, 5),
              IndexRange(3, 1)]
    plan = create_virtual_batches(ranges, 3, seed=2, drop_remainder=False)
    assert len(plan.batches) == 3                    # 8 samples, batches of 3
    assert [vb.size for vb in plan.batches] == [3, 3, 2]
    covered = np.concatenate([vb.global_ids for vb in plan.batches])
    assert sorted(covered.tolist()) == list(range(8))
