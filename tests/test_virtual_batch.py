"""Algorithm 1 properties: coverage, permutation, traversal-plan coherence.

Property-based via hypothesis: for any node population, every virtual batch
must (a) reference only valid (node, local) pairs, (b) cover each global id
at most once per epoch, (c) order traversal segments by first appearance,
(d) partition the batch's positions exactly.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.virtual_batch import (IndexRange, create_virtual_batches,
                                      global_reindex, make_traversal)


@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=8),
       batch=st.integers(1, 32), seed=st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_plan_properties(sizes, batch, seed):
    ranges = [IndexRange(i, n) for i, n in enumerate(sizes)]
    total = sum(sizes)
    plan = create_virtual_batches(ranges, min(batch, total), seed=seed)
    seen = set()
    for vb in plan.batches:
        positions = np.concatenate([s.batch_positions for s in vb.traversal])
        # traversal partitions the batch positions exactly
        assert sorted(positions.tolist()) == list(range(vb.size))
        # each node visited at most once per batch
        node_ids = [s.node_id for s in vb.traversal]
        assert len(node_ids) == len(set(node_ids))
        for seg in vb.traversal:
            n = sizes[seg.node_id]
            assert np.all(seg.local_indices >= 0)
            assert np.all(seg.local_indices < n)
            # the segment's rows really belong to that node
            gids = vb.global_ids[seg.batch_positions]
            assert np.all(plan.global_to_node[gids] == seg.node_id)
            assert np.array_equal(plan.global_to_local[gids],
                                  seg.local_indices)
        for g in vb.global_ids:
            assert g not in seen   # no duplicate sample within an epoch
            seen.add(int(g))
    # with drop_remainder, every complete batch is covered
    n_batches = total // min(batch, total)
    assert len(plan.batches) == n_batches


def test_global_reindex_bijection():
    ranges = [IndexRange(0, 10), IndexRange(1, 5), IndexRange(2, 7)]
    node_of, local_of = global_reindex(ranges)
    pairs = set(zip(node_of.tolist(), local_of.tolist()))
    assert len(pairs) == 22
    # randomized ids (§5.3) preserve the bijection
    node_r, local_r = global_reindex(ranges, randomize_ids=True, seed=3)
    assert set(zip(node_r.tolist(), local_r.tolist())) == pairs


def test_traversal_first_appearance_order():
    node_of = np.array([0, 0, 1, 1, 2, 2])
    local_of = np.array([0, 1, 0, 1, 0, 1])
    gids = np.array([4, 0, 5, 2])      # first appearance: node2, node0, node1
    segs = make_traversal(gids, node_of, local_of)
    assert [s.node_id for s in segs] == [2, 0, 1]


def test_shuffling_differs_across_epochs():
    ranges = [IndexRange(0, 64)]
    p0 = create_virtual_batches(ranges, 16, seed=0)
    p1 = create_virtual_batches(ranges, 16, seed=1)
    assert not np.array_equal(p0.batches[0].global_ids,
                              p1.batches[0].global_ids)
