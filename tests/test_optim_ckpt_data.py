"""Substrates: optimizers, checkpointing round-trip, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data.datasets import (imbalanced_binary, shard_cluster,
                                 shard_noniid, tabular, text_tokens)
from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                 synthetic_corpus)
from repro.optim import adafactor, adam, adamw, sgd, warmup_cosine


def _quadratic(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "m": jnp.zeros((4, 3))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    g = jax.jit(jax.grad(loss))
    for _ in range(steps):
        params, state = opt.update(params, g(params), state)
    return float(loss(params))


@pytest.mark.parametrize("opt_fn,steps,tol", [
    (lambda: sgd(0.1), 200, 1e-2),
    (lambda: sgd(0.05, momentum=0.9), 200, 1e-2),
    (lambda: adam(0.05), 200, 1e-2),
    (lambda: adamw(0.05, weight_decay=0.0), 200, 1e-2),
    # adafactor's relative-step second-moment decay converges more slowly on
    # tiny quadratics; assert steady progress rather than machine precision
    (lambda: adafactor(0.3), 2000, 5e-2),
], ids=["sgd", "sgd_mom", "adam", "adamw", "adafactor"])
def test_optimizers_converge(opt_fn, steps, tol):
    assert _quadratic(opt_fn(), steps) < tol


def test_grad_clipping_bounds_update():
    opt = sgd(1.0, clip_norm=0.1)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    p2, _ = opt.update(p, g, opt.init(p))
    assert float(jnp.linalg.norm(p2["w"])) <= 0.1 + 1e-6


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) < 0.11
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 0.01
    assert float(fn(jnp.asarray(100))) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, tree, extra={"note": "x"})
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    restored, meta = load_checkpoint(d, tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        load_checkpoint(d, {"zzz": jnp.zeros(2)})


# ------------------------------------------------------------------- data

def test_noniid_sharding_skews_labels():
    ds = tabular(800, 16, 4, seed=0)
    shards = shard_noniid(ds, 4, alpha=0.2, seed=1)
    assert sum(len(s.x) for s in shards) >= 0.95 * 800
    # at least one shard must be heavily skewed
    fracs = []
    for s in shards:
        counts = np.bincount(s.y, minlength=4) / max(len(s.y), 1)
        fracs.append(counts.max())
    assert max(fracs) > 0.5


def test_cluster_sharding_partitions():
    ds = tabular(300, 8, 3, seed=0)
    shards = shard_cluster(ds, 3, seed=0)
    assert sum(len(s.x) for s in shards) == 300


def test_imbalanced_binary_ratio():
    ds = imbalanced_binary(2000, pos_frac=0.15, seed=0)
    frac = ds.y.mean()
    assert 0.1 < frac < 0.2


def test_text_tokens_class_signal():
    ds = text_tokens(400, seq_len=24, vocab=64, seed=0)
    # class-conditional token histograms must differ
    h0 = np.bincount(ds.x[ds.y == 0].ravel(), minlength=64)
    h1 = np.bincount(ds.x[ds.y == 1].ravel(), minlength=64)
    h0 = h0 / h0.sum()
    h1 = h1 / h1.sum()
    assert np.abs(h0 - h1).sum() > 0.2


@given(n_nodes=st.integers(1, 6), batch=st.integers(2, 16))
@settings(max_examples=15, deadline=None)
def test_virtual_batch_loader_rows_match_plan(n_nodes, batch):
    docs = synthetic_corpus(48, 16, 97, seed=3)
    shards = shard_corpus(docs, n_nodes)
    loader = VirtualBatchLoader(shards, batch, seed=0, epochs=1)
    plan = loader.plan(0)
    batches = list(loader)
    assert len(batches) == len(plan.batches)
    for vb, got in zip(plan.batches, batches):
        assert got["tokens"].shape == (vb.size, 16)
        # rows are the documents named by the traversal plan (node-major)
        expect = np.concatenate(
            [loader.shards[s.node_id].docs[s.local_indices]
             for s in vb.traversal])
        np.testing.assert_array_equal(got["tokens"], expect[:, :-1])
        np.testing.assert_array_equal(got["targets"], expect[:, 1:])
