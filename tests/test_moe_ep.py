"""shard_map expert-parallel MoE (beyond-paper §Perf lever).

Runs in a subprocess with 4 virtual devices; asserts the EP path matches
the GSPMD path numerically and differentiates.
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import moe as M
    from repro.models.moe_ep import moe_apply_ep

    cfg = get_config("deepseek-v2-236b", reduced=True)
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(2, 2)
    key = jax.random.PRNGKey(0)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(key, (4, 8, cfg.d_model)) * 0.1
    ref, _ = M.moe_apply(p, cfg, x)
    with mesh:
        out, aux = jax.jit(lambda pp, xx: moe_apply_ep(pp, cfg, xx, mesh))(p, x)
        g = jax.jit(jax.grad(
            lambda pp: jnp.sum(moe_apply_ep(pp, cfg, x, mesh)[0] ** 2)))(p)
    err = float(jnp.abs(out - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert err < 2e-3, f"EP mismatch {err}"
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    assert float(jnp.abs(g["w_gate"]).max()) > 0
    print("EP_OK", err)
""")


def test_ep_moe_matches_gspmd_and_differentiates():
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "EP_OK" in proc.stdout
