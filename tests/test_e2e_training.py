"""End-to-end integration: the train/serve drivers run and losses go down."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_loss_decreases(tmp_path):
    losses = train_mod.main([
        "--arch", "deepseek-7b", "--steps", "30", "--nodes", "4",
        "--batch", "8", "--seq", "32", "--lr", "3e-3",
        "--ckpt", str(tmp_path / "ck")])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_driver_moe_arch():
    losses = train_mod.main([
        "--arch", "deepseek-v2-236b", "--steps", "12", "--nodes", "2",
        "--batch", "4", "--seq", "32", "--lr", "3e-3"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 0.5


def test_serve_driver_generates():
    tokens = serve_mod.main(["--arch", "starcoder2-3b", "--batch", "2",
                             "--prompt-len", "16", "--gen", "8"])
    assert tokens.shape == (2, 8)
    assert bool(jnp.all(tokens >= 0))


def test_serve_driver_ssm():
    tokens = serve_mod.main(["--arch", "mamba2-780m", "--batch", "2",
                             "--prompt-len", "16", "--gen", "8"])
    assert tokens.shape == (2, 8)


def test_checkpoint_resume_produces_same_params(tmp_path):
    """The CLI's final checkpoint uses the engine's resume-able layout
    ({params, opt_state} + step meta) — the same file --resume restores."""
    from repro.checkpoint import load_checkpoint
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import adamw, warmup_cosine

    d = str(tmp_path / "ck")
    train_mod.main(["--arch", "deepseek-7b", "--steps", "6", "--nodes", "2",
                    "--batch", "4", "--seq", "32", "--ckpt", d])
    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(warmup_cosine(3e-4, 10, 6), clip_norm=1.0)
    tree = {"params": params, "opt_state": opt.init(params)}
    restored, meta = load_checkpoint(d, tree)
    assert meta["step"] == 6 and meta["extra"]["step"] == 6
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(restored))


def test_train_cli_kill_resume_bitequal(tmp_path):
    """--ckpt-every + --resume: a killed CLI run resumes and finishes with
    exactly the uninterrupted run's parameters."""
    from repro.checkpoint import load_checkpoint
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import adamw, warmup_cosine

    # the killed run keeps the FULL --steps budget (its LR schedule horizon)
    # and dies mid-run via the --halt-at crash drill — resuming with a
    # different budget is refused (config-mismatch guard, tested below)
    args = ["--arch", "deepseek-7b", "--nodes", "2", "--batch", "4",
            "--seq", "32", "--lr", "3e-3", "--steps", "6"]
    d_full, d_part = str(tmp_path / "full"), str(tmp_path / "part")
    train_mod.main(args + ["--ckpt", d_full])
    train_mod.main(args + ["--ckpt", d_part, "--ckpt-every", "3",
                           "--halt-at", "3"])
    train_mod.main(args + ["--ckpt", d_part, "--resume"])

    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(warmup_cosine(3e-3, 10, 6), clip_norm=1.0)
    tree = {"params": params, "opt_state": opt.init(params)}
    a, _ = load_checkpoint(d_full, tree)
    b, _ = load_checkpoint(d_part, tree)
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))

    # resuming under a different config must fail loudly, not silently
    # replay different arithmetic (the schedule horizon changes past warmup)
    import pytest
    with pytest.raises(SystemExit):
        train_mod.main(["--arch", "deepseek-7b", "--nodes", "2", "--batch",
                        "4", "--seq", "32", "--lr", "3e-3", "--steps", "12",
                        "--ckpt", d_part, "--resume"])
