"""Fault-tolerant traversal: losslessness-under-faults acceptance grid.

TL's claim is exact (bit-level) equivalence with centralized training; the
fault subsystem (``repro.core.faults``) must preserve that claim while the
transport drops visit payloads, straggles nodes, or the whole run is killed
and resumed.  The acceptance grid —

    {fused, eager} × {drop, straggle, kill+resume} × {2, 3 uneven nodes}

— asserts the recovered run's losses and parameters are **bit-equal**
(stronger than the f32-ULP criterion) to the fault-free run once recovery
completes, and that recovery is visible where it should be: the simulated
clock grows, the byte counters grow by exactly the retried payloads, and
the reassembly invariant (every virtual-batch row assembled exactly once)
is re-verified after re-planning.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.paper_models import DATRET
from repro.core.faults import (FaultInjector, FaultSpec, RecoveryPolicy,
                               UnrecoverableFault, fault_expansion)
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.core.virtual_batch import NodeSegment, assert_exactly_once
from repro.models.small import SmallModel
from repro.optim import sgd

DROP = FaultSpec(drop_prob=0.4, seed=11)
STRAGGLE = FaultSpec(straggle_prob=0.6, straggle_factor=3.0, seed=11)


def _build(sizes, *, fused=True, fault=None, replicas=True, pipelined=False,
           seed=7, recovery=None):
    """An orchestrator over uneven shards, optionally fault-injected, with
    replica nodes holding bit-identical copies of each primary's shard."""
    model = SmallModel(DATRET)
    r = np.random.default_rng(seed)
    data = [(r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
             r.integers(0, DATRET.n_classes, n)) for n in sizes]
    nodes = [TLNode(i, model, x, y, jit_visits=fused)
             for i, (x, y) in enumerate(data)]
    reps = ({i: TLNode(100 + i, model, x, y, jit_visits=fused)
             for i, (x, y) in enumerate(data)} if replicas else None)
    tr = Transport(faults=FaultInjector(fault) if fault else None)
    orch = TLOrchestrator(model, nodes, sgd(0.05), tr,
                          batch_size=16, fused=fused, pipelined=pipelined,
                          plan=PlanSpec(
                              seed=0, replicas=reps,
                              recovery=recovery
                              or RecoveryPolicy(backoff_s=0.01)),
                          compute_time_fn=lambda k: 1e-4 * k,
                          bp_time_fn=lambda n: 5e-4 * n)
    orch.initialize(jax.random.PRNGKey(3))
    return orch


def _assert_bitequal(a, b):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def _assert_stats_equal(sa, sb):
    assert len(sa) == len(sb) >= 1
    for x, y in zip(sa, sb):
        assert x.loss == y.loss
        assert x.acc == y.acc


# ------------------------------------------------------- the acceptance grid
@pytest.mark.parametrize("sizes", [[20, 12], [13, 8, 11]],
                         ids=["2nodes-uneven", "3nodes-uneven"])
@pytest.mark.parametrize("mode", ["drop", "straggle", "kill_resume"])
@pytest.mark.parametrize("fused", [True, False], ids=["fused", "eager"])
def test_lossless_under_faults_grid(fused, mode, sizes, tmp_path):
    """{fused, eager} × {drop, straggle, kill+resume} × {2,3 uneven nodes}:
    losses and params bit-equal to the fault-free run after recovery."""
    clean = _build(sizes, fused=fused)
    clean_stats = [s for _ in range(2) for s in clean.train_epoch()]

    if mode == "kill_resume":
        # run epoch 0 + one batch of epoch 1, checkpoint at the step
        # boundary, 'kill', restore into a fresh orchestrator, finish
        part = _build(sizes, fused=fused)
        s0 = part.train_epoch()
        s1 = part.train_epoch(max_batches=1)
        part.save(str(tmp_path))
        resumed = _build(sizes, fused=fused)
        start = resumed.restore(str(tmp_path))
        assert start == 1 and resumed.step == part.step
        s2 = resumed.train_epoch(start_batch=start)
        _assert_bitequal(clean.params, resumed.params)
        _assert_stats_equal(clean_stats, s0 + s1 + s2)
        return

    fault = DROP if mode == "drop" else STRAGGLE
    faulty = _build(sizes, fused=fused, fault=fault)
    faulty_stats = [s for _ in range(2) for s in faulty.train_epoch()]

    _assert_bitequal(clean.params, faulty.params)
    _assert_stats_equal(clean_stats, faulty_stats)
    # recovery must actually have happened, and be visible on the clock
    assert faulty.transport.fault_log, "seeded spec injected no faults"
    assert faulty.transport.clock_s > clean.transport.clock_s
    if mode == "straggle":
        # stragglers are slow, not lossy: byte accounting is untouched
        assert faulty.transport.bytes_sent == clean.transport.bytes_sent
    else:
        # retries re-send payloads: bytes can only grow
        assert faulty.transport.total_bytes > clean.transport.total_bytes


def test_retry_wire_time_visible_without_backoff():
    """The retried upload itself must advance the simulated clock even with
    zero backoff and zero modeled compute: a segment's attempts are
    sequential on the wire (Transport.chain), so a dropped attempt cannot
    hide under the parallel window's max()."""
    def build(fault):
        model = SmallModel(DATRET)
        r = np.random.default_rng(7)
        data = [(r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
                 r.integers(0, DATRET.n_classes, n)) for n in [20, 12]]
        nodes = [TLNode(i, model, x, y) for i, (x, y) in enumerate(data)]
        reps = {i: TLNode(100 + i, model, x, y)
                for i, (x, y) in enumerate(data)}
        tr = Transport(faults=FaultInjector(fault) if fault else None)
        orch = TLOrchestrator(model, nodes, sgd(0.05), tr, batch_size=16,
                              plan=PlanSpec(seed=0, replicas=reps,
                                            recovery=RecoveryPolicy()))
        # RecoveryPolicy() default: backoff_s=0
        orch.initialize(jax.random.PRNGKey(3))
        return orch

    clean, faulty = build(None), build(DROP)
    for _ in range(2):
        clean.train_epoch()
        faulty.train_epoch()
    _assert_bitequal(clean.params, faulty.params)
    assert any(e.kind == "drop" for e in faulty.transport.fault_log)
    assert faulty.transport.clock_s > clean.transport.clock_s


def test_pipelined_recovery_matches_serial():
    """Fault recovery composes with the double-buffered epoch engine: the
    seeded per-visit verdicts are order-independent, so the pipelined
    faulty run recovers to the same bits as the serial faulty run and the
    fault-free run."""
    clean = _build([13, 8, 11])
    serial = _build([13, 8, 11], fault=DROP)
    piped = _build([13, 8, 11], fault=DROP, pipelined=True)
    for _ in range(2):
        clean.train_epoch()
        serial.train_epoch()
        piped.train_epoch()
    _assert_bitequal(clean.params, serial.params)
    _assert_bitequal(clean.params, piped.params)
    # same faults were drawn on both paths (order-independence)
    assert ([e.key for e in serial.transport.fault_log]
            == [e.key for e in piped.transport.fault_log])
    assert serial.transport.bytes_sent == piped.transport.bytes_sent


def test_retried_bytes_accounted_exactly_once():
    """The faulty run's activation bytes exceed the clean run's by exactly
    the sum of the dropped attempts' payload bytes (window_log
    ``fault:drop`` records) — retries are charged, successes are never
    double-counted.  The only other growth is the failover model re-sends,
    visible on the ``model`` tag."""
    clean = _build([20, 12])
    faulty = _build([20, 12], fault=DROP)
    for _ in range(2):
        clean.train_epoch()
        faulty.train_epoch()
    dropped = {}
    for rec in faulty.transport.window_log:
        if rec.kind == "fault:drop":
            for tag, nb in rec.by_tag.items():
                dropped[tag] = dropped.get(tag, 0) + nb
    assert set(dropped) == {"activations_grads"}
    assert (faulty.transport.bytes_sent["activations_grads"]
            == clean.transport.bytes_sent["activations_grads"]
            + dropped["activations_grads"])
    # the model tag grows only by whole failover re-sends: one model
    # payload per "failover" recovery event, nothing else
    extra_model = (faulty.transport.bytes_sent["model"]
                   - clean.transport.bytes_sent["model"])
    failovers = sum(1 for e in faulty.fault_log if e.kind == "failover")
    if failovers:
        assert extra_model > 0 and extra_model % failovers == 0
    else:
        assert extra_model == 0


def test_unrecoverable_without_replica():
    """Exhausted retries with no replica must fail loudly, never assemble a
    partial virtual batch."""
    orch = _build([20, 12], fault=FaultSpec(drop_prob=0.95, seed=1),
                  replicas=False)
    with pytest.raises(UnrecoverableFault):
        for _ in range(4):
            orch.train_epoch()


def test_replica_tried_even_when_failover_threshold_misconfigured():
    """retries_before_failover > max_attempts must not strand a configured
    replica: failover is taken as the last act before giving up, so a
    'failover' event always precedes any UnrecoverableFault.  (A 2-attempt
    budget can still legitimately exhaust if the replica's own attempts
    drop — the guarantee is that the replica was *tried*.)"""
    faulty = _build([20, 12], fault=DROP,
                    recovery=RecoveryPolicy(max_attempts=2,
                                            retries_before_failover=5))
    try:
        for _ in range(2):
            faulty.train_epoch()
    except UnrecoverableFault:
        pass
    assert any(e.kind == "failover" for e in faulty.fault_log)


def test_eviction_replans_mid_epoch():
    """A node whose failures reach evict_after is evicted: later segments
    route straight to the replica (no retry burn on the dead primary), and
    training still matches the fault-free bits."""
    clean = _build([20, 12])
    # a brutal 0.7 drop rate needs a deep retry budget: the replica's own
    # attempts are faulty too, and 0.7^8 per-segment exhaustion odds would
    # make the default max_attempts flaky by design
    faulty = _build([20, 12], fault=FaultSpec(drop_prob=0.7, seed=3),
                    recovery=RecoveryPolicy(max_attempts=64, backoff_s=0.01))
    for _ in range(2):
        clean.train_epoch()
        faulty.train_epoch()
    _assert_bitequal(clean.params, faulty.params)
    assert any(e.kind == "evict" for e in faulty.fault_log)
    assert any(h.evicted for h in faulty._health.values())


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["serial", "pipelined"])
def test_cached_mode_recovery_spans_epochs(pipelined):
    """§5.2 model caching + faults: an evicted primary's replica must
    receive the *epoch-start* parameters at the next epoch's distribution
    (not keep the params from the failover that evicted the primary), so
    cached-mode recovery stays bit-equal to the fault-free cached run."""
    def build(fault):
        model = SmallModel(DATRET)
        r = np.random.default_rng(7)
        data = [(r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
                 r.integers(0, DATRET.n_classes, n)) for n in [20, 12]]
        nodes = [TLNode(i, model, x, y) for i, (x, y) in enumerate(data)]
        reps = {i: TLNode(100 + i, model, x, y)
                for i, (x, y) in enumerate(data)}
        tr = Transport(faults=FaultInjector(fault) if fault else None)
        orch = TLOrchestrator(
            model, nodes, sgd(0.05), tr, batch_size=16,
            cache_model_per_epoch=True, pipelined=pipelined,
            plan=PlanSpec(seed=0, replicas=reps,
                          recovery=RecoveryPolicy(max_attempts=64,
                                                  evict_after=2,
                                                  backoff_s=0.01)))
        orch.initialize(jax.random.PRNGKey(3))
        return orch

    clean = build(None)
    faulty = build(FaultSpec(drop_prob=0.7, seed=3))
    for _ in range(3):
        clean.train_epoch()
        faulty.train_epoch()
    assert any(e.kind == "evict" for e in faulty.fault_log)
    _assert_bitequal(clean.params, faulty.params)


def test_fault_decisions_are_order_independent():
    """The injector's verdict is a pure function of (seed, key): identical
    across repeated queries and across differently-ordered query streams;
    the attempt index is part of the key so retries get fresh draws."""
    inj = FaultInjector(FaultSpec(drop_prob=0.5, straggle_prob=0.3,
                                  straggle_factor=2.0, seed=42))
    keys = [(e, b, n, a) for e in range(3) for b in range(3)
            for n in range(3) for a in range(3)]
    fwd = [inj.decide(k).kind for k in keys]
    rev = [inj.decide(k).kind for k in reversed(keys)]
    assert fwd == list(reversed(rev))
    assert {"ok", "drop"} <= set(fwd)           # both outcomes occur
    # fresh injector, same spec -> same stream
    again = FaultInjector(FaultSpec(drop_prob=0.5, straggle_prob=0.3,
                                    straggle_factor=2.0, seed=42))
    assert fwd == [again.decide(k).kind for k in keys]


_KEY = st.tuples(st.integers(0, 5), st.integers(0, 63),
                 st.integers(0, 7), st.integers(0, 3))


@given(seed=st.integers(0, 2**31 - 1),
       drop=st.floats(0.0, 0.6), straggle=st.floats(0.0, 0.6),
       keys=st.lists(_KEY, min_size=1, max_size=48),
       split=st.integers(0, 47))
@settings(max_examples=30, deadline=None)
def test_fault_verdicts_order_independent_property(seed, drop, straggle,
                                                   keys, split):
    """Property: for any spec and any (epoch, batch, node, attempt) key
    stream, the verdicts are identical whether the stream is consulted
    serially, in pipelined (reversed/interleaved) order, or re-issued from
    an arbitrary split point after a mid-epoch eviction re-plan — the
    verdict is a pure function of (seed, key), never of consultation
    history.  (Runs under the real hypothesis when installed, else the
    seeded shim in conftest.)"""
    spec = FaultSpec(drop_prob=drop, straggle_prob=straggle, seed=seed)
    inj = FaultInjector(spec)
    serial = [(inj.decide(k).kind, inj.decide(k).factor) for k in keys]
    # pipelined: a fresh injector consulted in reversed order
    pipelined = [(o.kind, o.factor)
                 for o in (FaultInjector(spec).decide(k)
                           for k in reversed(keys))]
    assert serial == list(reversed(pipelined))
    # re-issued after eviction: replay an arbitrary suffix mid-stream
    cut = split % len(keys)
    replant = [(inj.decide(k).kind, inj.decide(k).factor)
               for k in keys[cut:]]
    assert replant == serial[cut:]


def test_exactly_once_assertion_catches_corruption():
    seg = NodeSegment(0, np.arange(4), np.arange(4))
    assert_exactly_once(4, [seg])               # clean partition passes
    dup = NodeSegment(1, np.arange(4), np.array([3, 4, 5, 3]))
    with pytest.raises(RuntimeError, match="exactly once"):
        assert_exactly_once(8, [seg, dup])
    with pytest.raises(RuntimeError, match="lost or duplicated"):
        assert_exactly_once(8, [seg])


def test_async_tl_survives_faults():
    """The async (§3.4) path retries dropped visits within the recovery
    budget and skips persistently-failing contributions instead of dying —
    async mode trades exactness for liveness by design."""
    from repro.core.async_tl import async_train_epoch

    orch = _build([20, 12], fault=FaultSpec(drop_prob=0.4, seed=5),
                  replicas=False)
    stats, tracker = async_train_epoch(orch)
    assert stats, "async epoch produced no updates under faults"
    assert all(np.isfinite(s.loss) for s in stats)
    assert orch.transport.fault_log      # faults actually fired


def test_fault_expansion_closed_form():
    assert fault_expansion() == 1.0
    assert abs(fault_expansion(drop_prob=0.5) - 2.0) < 1e-12
    assert abs(fault_expansion(straggle_prob=0.5, straggle_factor=3.0)
               - 2.0) < 1e-12
    # monotone in each knob
    assert (fault_expansion(0.3, 0.5, 4.0)
            > fault_expansion(0.1, 0.5, 4.0)
            > fault_expansion(0.1, 0.2, 4.0)
            > fault_expansion() )


# ------------------------------------------------- engine checkpoint/resume
def _prod_engine(cfg, model, mesh, shape, **kw):
    from repro.launch.engine import Engine
    from repro.optim import adamw
    eng = Engine(model, cfg, adamw(3e-3, clip_norm=1.0), mesh, shape, **kw)
    return eng


def test_engine_production_kill_resume(tmp_path):
    """Production engine: a run killed after a step-boundary checkpoint
    resumes via ``Engine.restore`` and finishes bit-identical to an
    uninterrupted run (the loader tail is a pure function of its seed)."""
    from repro.configs import get_config
    from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                     synthetic_corpus)
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.configs.base import InputShape

    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    mesh = make_debug_mesh(1, 1)
    shape = InputShape("t", 32, 8, "train")

    def loader():
        docs = synthetic_corpus(64, 32, cfg.vocab_size, seed=1)
        return VirtualBatchLoader(shard_corpus(docs, 4), 8, seed=0)

    full = _prod_engine(cfg, model, mesh, shape)
    full.init(jax.random.PRNGKey(0))
    ra = full.run(loader(), steps=6)

    killed = _prod_engine(cfg, model, mesh, shape,
                          ckpt_dir=str(tmp_path), ckpt_every=3)
    killed.init(jax.random.PRNGKey(0))
    killed.run(loader(), steps=3)                 # dies at the boundary

    resumed = _prod_engine(cfg, model, mesh, shape, ckpt_dir=str(tmp_path))
    assert resumed.restore() == 3
    # a budget at/behind the resume cursor fails loudly AND keeps the
    # cursor armed: the retried run below must still resume at step 3,
    # never silently replay batches 0-2 onto the restored params
    with pytest.raises(ValueError, match="nothing to run"):
        resumed.run(loader(), steps=3)
    rc = resumed.run(loader(), steps=6)           # global budget: runs 3
    assert rc.steps == 3
    _assert_bitequal(ra.params, rc.params)
    np.testing.assert_array_equal(ra.losses[3:], rc.losses)


def test_engine_sim_kill_resume(tmp_path):
    """Sim-mode engine: epoch-boundary checkpoints + lazy restore give the
    same bits as an uninterrupted sim run."""
    from repro.core.baselines import ShardData
    from repro.launch.engine import Engine

    r = np.random.default_rng(5)
    shards = [ShardData(
        r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
        r.integers(0, DATRET.n_classes, n)) for n in [20, 12]]
    model = SmallModel(DATRET)

    full = Engine(model, DATRET, sgd(0.05), mode="sim", batch_size=16,
                  seed=0)
    rf = full.run(shards, epochs=3)

    part = Engine(model, DATRET, sgd(0.05), mode="sim", batch_size=16,
                  seed=0, ckpt_dir=str(tmp_path))
    part.run(shards, epochs=2)                    # saved at epoch boundary

    res = Engine(model, DATRET, sgd(0.05), mode="sim", batch_size=16,
                 seed=0, ckpt_dir=str(tmp_path))
    res.restore()
    rr = res.run(shards, epochs=1)
    _assert_bitequal(rf.params, rr.params)
    np.testing.assert_array_equal(rf.losses[-rr.steps:], rr.losses)
