"""dist.constraints behaviour + launch.specs shapes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_shape
from repro.dist.constraints import (activation_sharding, constrain_batch,
                                    set_activation_mesh)
from repro.launch.specs import input_specs, text_len


def test_constrain_noop_without_mesh():
    set_activation_mesh(None)
    x = jnp.ones((4, 8))
    assert constrain_batch(x) is x


def test_activation_sharding_context_restores():
    set_activation_mesh(None)
    with activation_sharding(("data",)):
        pass
    x = jnp.ones((4, 8))
    assert constrain_batch(x) is x     # restored to None


def test_constraint_lowers_inside_jit():
    """with_sharding_constraint must trace under a (1-device) mesh."""
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    with activation_sharding(("data",)):
        with mesh:
            out = jax.jit(lambda x: constrain_batch(x) * 2)(jnp.ones((2, 3)))
    assert out.shape == (2, 3)


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k", "long_500k"])
def test_input_specs_shapes(shape_name):
    shape = get_shape(shape_name)
    for arch in ["deepseek-7b", "qwen2-vl-72b", "seamless-m4t-medium"]:
        cfg = get_config(arch)
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            B, S = specs["tokens"].shape
            assert B == shape.global_batch
            total = S + (cfg.frontend_tokens if (cfg.frontend and
                                                 not cfg.is_encdec) else 0)
            assert total == shape.seq_len
            if cfg.frontend:
                assert specs["embeds"].shape == (B, cfg.frontend_tokens,
                                                 cfg.d_model)
        elif shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch,)
            assert specs["cache_len"].shape == ()


def test_vlm_text_len_accounts_frontend():
    cfg = get_config("qwen2-vl-72b")
    assert text_len(cfg, get_shape("train_4k")) == 4096 - 256
    enc = get_config("seamless-m4t-medium")
    assert text_len(enc, get_shape("train_4k")) == 4096   # enc-dec: decoder full len
