import sys
import types

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# --------------------------------------------------------------------------
# `slow` marker: multi-epoch equivalence-grid cells (and other nightly-depth
# tests) are skipped by the tier-1 run (`pytest -x -q`); run them with
# `pytest --runslow` (or `-m slow` plus --runslow for only them).
# --------------------------------------------------------------------------
def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked slow (nightly depth)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: nightly-depth test, skipped unless --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: nightly depth, use --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


# --------------------------------------------------------------------------
# Minimal deterministic `hypothesis` shim.
#
# The property tests use a small slice of the hypothesis API (given /
# settings / strategies.{integers,floats,lists}).  When the real package is
# unavailable we install a seeded stand-in that draws `max_examples` random
# examples per test, so the property tests still run (with fixed seeds)
# instead of failing at collection.  If hypothesis is installed it wins.
# --------------------------------------------------------------------------
try:                                                    # pragma: no cover
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

    def _floats(lo, hi, **_kw):
        return _Strategy(lambda r: float(r.uniform(lo, hi)))

    def _booleans():
        return _Strategy(lambda r: bool(r.integers(0, 2)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: seq[int(r.integers(0, len(seq)))])

    def _lists(elem, min_size=0, max_size=10):
        def draw(r):
            n = int(r.integers(min_size, max_size + 1))
            return [elem.draw(r) for _ in range(n)]
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda r: tuple(e.draw(r) for e in elems))

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def _given(**strategies):
        def deco(fn):
            n = getattr(fn, "_hyp_max_examples", 20)

            # no functools.wraps: pytest must see the (*args, **kwargs)
            # signature, not the original one (whose params would otherwise
            # be resolved as fixtures)
            def wrapper(*args, **kwargs):
                r = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(r) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_repro_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
