"""Property test for `repro.dist.sharding.param_specs`: every emitted
PartitionSpec is realizable — each sharded dim is divided exactly by the
product of its mesh-axis sizes — on both the debug mesh and a forced
8-device CPU mesh.

Runs in a subprocess so the forced device count never leaks into other
tests (same pattern as test_sharding_multidevice).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import json
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from jax.sharding import PartitionSpec
    from repro.configs import get_config
    from repro.dist.sharding import _mesh_sizes, param_specs, tokens_pspec
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model

    def axes_product(entry, sizes):
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes[a]
        return n

    checked = 0
    for mesh in [make_debug_mesh(2, 2), make_debug_mesh(2, 4)]:
        sizes = _mesh_sizes(mesh)
        for arch in os.environ["TEST_ARCHS"].split(","):
            cfg = get_config(arch, reduced=True)
            m = build_model(cfg)
            params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
            specs = param_specs(params, cfg, mesh)
            flat_p = jax.tree.leaves(params)
            flat_s = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
            assert len(flat_p) == len(flat_s)
            for leaf, spec in zip(flat_p, flat_s):
                assert isinstance(spec, PartitionSpec)
                assert len(spec) <= leaf.ndim
                for dim, entry in zip(leaf.shape, spec):
                    if entry is None:
                        continue
                    n = axes_product(entry, sizes)
                    assert dim % n == 0, (arch, leaf.shape, tuple(spec))
                    checked += 1
        # batch specs obey the same rule
        for B in (1, 2, 3, 4, 8, 16):
            tok = tokens_pspec(mesh, B)
            if tok[0] is not None:
                assert B % axes_product(tok[0], sizes) == 0
    print("RESULT", json.dumps({"sharded_dims_checked": checked}))
""")


@pytest.mark.parametrize("archs", [
    "deepseek-7b,deepseek-v3-671b,mamba2-780m",
    "recurrentgemma-9b,qwen2.5-32b,starcoder2-3b",
])
def test_param_specs_divide_mesh_axes(archs):
    env = dict(os.environ, TEST_ARCHS=archs,
               PYTHONPATH=os.path.abspath("src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    data = json.loads(line.split("RESULT ")[1])
    # the property is vacuous if nothing ever shards — demand real coverage
    assert data["sharded_dims_checked"] > 50
