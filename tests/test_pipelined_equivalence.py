"""Cross-path lossless equivalence grid: the double-buffered epoch engine is
a *reordering* of the serial TL epoch, never an approximation.

For every execution-path combination {fused, eager} × {cache_model_per_epoch
on/off} × {2, 3 nodes with uneven shards}, training the same initialization
for ≥3 epochs through the pipelined engine and through the serial loop must
produce final parameters equal to within a few float32 ULPs (in practice the
paths are bit-identical: the engine issues exactly the same arithmetic in
the same order, only the simulated clock differs), identical per-step stats,
and identical per-tag byte accounting.

A deeper nightly variant (more epochs, a 4-node split including a
single-sample shard, donated buffers under prefetch) carries the ``slow``
marker and is skipped by the tier-1 run.
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import DATRET
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.pipeline import PipelinedEpochEngine
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.models.small import SmallModel
from repro.optim import sgd

# a handful of float32 ULPs at the parameters' magnitude: what jit fusion
# may legitimately reorder, and nothing more
ULP_FACTOR = 16


def _build(fused, cache, pipelined, sizes, *, donate=False, seed=7):
    model = SmallModel(DATRET)
    r = np.random.default_rng(seed)
    nodes = [TLNode(i, model,
                    r.normal(size=(n,) + DATRET.in_shape).astype(np.float32),
                    r.integers(0, DATRET.n_classes, n), jit_visits=fused)
             for i, n in enumerate(sizes)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=16, plan=PlanSpec(seed=0),
                          fused=fused, donate=donate,
                          cache_model_per_epoch=cache, pipelined=pipelined,
                          compute_time_fn=lambda k: 1e-4 * k,
                          bp_time_fn=lambda n: 5e-4 * n)
    orch.initialize(jax.random.PRNGKey(3))
    return orch


def _assert_param_equiv(serial, pipelined):
    eps = np.finfo(np.float32).eps
    for pa, pb in zip(jax.tree.leaves(serial.params),
                      jax.tree.leaves(pipelined.params)):
        a = np.asarray(pa, dtype=np.float64)
        b = np.asarray(pb, dtype=np.float64)
        tol = ULP_FACTOR * eps * max(1.0, float(np.abs(a).max()))
        assert np.abs(a - b).max() <= tol, \
            f"pipelined drifted {np.abs(a - b).max():.3e} > {tol:.3e}"


def _run_grid_cell(fused, cache, sizes, epochs):
    serial = _build(fused, cache, False, sizes)
    piped = _build(fused, cache, True, sizes)
    for _ in range(epochs):
        ss = serial.train_epoch()
        sp = piped.train_epoch()
        assert len(ss) == len(sp) >= 1
        for a, b in zip(ss, sp):
            assert abs(a.loss - b.loss) < 1e-6
            assert abs(a.acc - b.acc) < 1e-9
            if not np.isnan(a.grad_consistency):
                # identical across paths; < 1e-5 (eq. 12) only in strict
                # mode — model caching introduces the paper's §5.2
                # staleness in serial and pipelined alike
                assert abs(a.grad_consistency - b.grad_consistency) < 1e-6
                if not cache:
                    assert b.grad_consistency < 1e-5        # eq. 12 holds
    _assert_param_equiv(serial, piped)
    # overlap changes clock, never bytes (full per-tag accounting)
    assert serial.transport.bytes_sent == piped.transport.bytes_sent
    assert serial.transport.n_messages == piped.transport.n_messages
    assert piped.transport.clock_s < serial.transport.clock_s


@pytest.mark.parametrize("sizes", [[20, 12], [13, 8, 11]],
                         ids=["2nodes-uneven", "3nodes-uneven"])
@pytest.mark.parametrize("cache", [False, True],
                         ids=["strict", "cached"])
@pytest.mark.parametrize("fused", [True, False], ids=["fused", "eager"])
def test_pipelined_matches_serial(fused, cache, sizes):
    """The full {fused, eager} × {cache on/off} × {2,3 uneven nodes} grid,
    3 epochs per cell."""
    _run_grid_cell(fused, cache, sizes, epochs=3)


def test_pipelined_donate_safe_under_prefetch():
    """donate=True (fused strict): safe because every consumer of parameter
    generation g (batch g's visits) is dispatched before the step donating
    g is dispatched — the engine produces strictly after apply_update in
    each overlap scope.  Trajectory still matches the non-donating serial
    path."""
    serial = _build(True, False, False, [13, 8, 11])
    piped = _build(True, False, True, [13, 8, 11], donate=True)
    for _ in range(3):
        serial.train_epoch()
        piped.train_epoch()
    _assert_param_equiv(serial, piped)


def test_engine_queue_is_double_buffered():
    """The payload queue really double-buffers: it holds the batch being
    consumed plus the prefetched one (depth 2) and never more."""
    orch = _build(True, False, False, [20, 12])
    engine = PipelinedEpochEngine(orch)
    engine.run_epoch()
    # 32 samples / batch 16 -> 2 batches: prefetch reaches full depth
    assert engine.max_queue_depth == PipelinedEpochEngine.QUEUE_DEPTH
    assert not engine._queue                    # drained at epoch end


@pytest.mark.slow
@pytest.mark.parametrize("cache", [False, True], ids=["strict", "cached"])
@pytest.mark.parametrize("fused", [True, False], ids=["fused", "eager"])
def test_pipelined_matches_serial_deep(fused, cache):
    """Nightly depth: 6 epochs, 4 uneven nodes including a single-sample
    shard (exercises bucket padding + tiny tail segments under prefetch)."""
    _run_grid_cell(fused, cache, [13, 1, 11, 9], epochs=6)
