"""Per-assigned-architecture smoke tests (reduced configs, CPU).

For each of the 10 architectures: instantiate the reduced same-family
variant, run one forward and one TL train step, assert output shapes and
finiteness; run decode and check it matches the full forward.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.core.tl_step import make_train_step
from repro.models import build_model
from repro.optim import adam

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    extra = None
    if cfg.frontend:
        extra = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.d_model)) * 0.02
        batch["embeds"] = extra
    return batch, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_routed_experts <= 4
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    p = m.init(key)
    batch, extra = _batch(cfg, key)
    logits, aux = m.forward(p, batch["tokens"], extra)
    B, S = batch["tokens"].shape
    F = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encdec) else 0
    assert logits.shape == (B, S + F, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_tl_train_step(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    key = jax.random.PRNGKey(1)
    p = m.init(key)
    opt = adam(1e-3)
    st = opt.init(p)
    step = jax.jit(make_train_step(m, cfg, opt))
    batch, _ = _batch(cfg, key)
    p2, st2, loss = step(p, st, batch)
    assert bool(jnp.isfinite(loss))
    # parameters actually moved
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)))
    assert moved
    # no NaNs anywhere in the updated tree
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    key = jax.random.PRNGKey(2)
    p = m.init(key)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = None
    if cfg.frontend:
        extra = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.d_model)) * 0.02
    cache = m.init_cache(B, max_len=S)
    if cfg.is_encdec:
        from repro.models import encdec
        cache["enc_out"] = encdec.encode(p, cfg, extra)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(p, cache, tokens[:, t],
                                  jnp.asarray(t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    ref, _ = m.forward(p, tokens, extra if cfg.is_encdec else None)
    ref = ref[:, :S] if not cfg.frontend or cfg.is_encdec else \
        m.forward(p, tokens, None)[0][:, :S]
    rel = float(jnp.abs(dec - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 2e-3, f"decode diverges from forward: {rel}"


@pytest.mark.parametrize("arch", ["starcoder2-3b", "mamba2-780m",
                                  "recurrentgemma-9b"])
def test_long_context_archs_have_bounded_caches(arch):
    """The long_500k-eligible archs must have O(window/state) caches."""
    cfg = get_config(arch)          # FULL config
    m = build_model(cfg)
    cache = jax.eval_shape(lambda: m.init_cache(1, 524_288))
    total = sum(int(jnp.prod(jnp.asarray(l.shape))) * l.dtype.itemsize
                for l in jax.tree.leaves(cache))
    # bounded: far below a dense 500k KV cache of the same model
    assert total < 4e9, f"cache {total/1e9:.1f}GB is not bounded"
