"""End-to-end driver: train a ~100M-parameter LM with the production TL step.

The model is a scaled-down llama-family config (deepseek-7b family), the
data pipeline is Algorithm 1's virtual-batch loader over 8 node shards, and
the train step is the pjit TL step (remat-from-X^(1), node-axis gradient
aggregation) — the same code path the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/train_tl_100m.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_tl_100m.py --tiny     # CI-sized
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.tl_step import make_train_step
from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                 synthetic_corpus)
from repro.models import build_model
from repro.optim import adamw, warmup_cosine


def config_100m():
    base = get_config("deepseek-7b")
    return dataclasses.replace(
        base, name="deepseek-100m", n_layers=8, d_model=640, n_heads=10,
        n_kv_heads=10, head_dim=64, d_ff=1792, vocab_size=32000)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/tl_100m_ckpt")
    args = ap.parse_args(argv)

    cfg = get_config("deepseek-7b", reduced=True) if args.tiny else config_100m()
    if args.tiny:
        args.steps = min(args.steps, 20)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.nodes} nodes, batch {args.batch}, seq {args.seq}")

    opt = adamw(warmup_cosine(3e-4, 20, args.steps), clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt, remat_mode="tl"))

    docs = synthetic_corpus(args.nodes * 128, args.seq, cfg.vocab_size, seed=1)
    shards = shard_corpus(docs, args.nodes)
    loader = VirtualBatchLoader(shards, args.batch, seed=0)

    losses, t0 = [], time.time()
    for step, batch in enumerate(loader):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if step % 10 == 0:
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {losses[-1]:7.4f}  {tok_s:7.0f} tok/s")
    print(f"loss: {np.mean(losses[:10]):.4f} -> {np.mean(losses[-10:]):.4f}")
    save_checkpoint(args.ckpt, args.steps, {"params": params})
    print("checkpoint saved to", args.ckpt)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not improve"


if __name__ == "__main__":
    main()
