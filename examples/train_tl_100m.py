"""End-to-end driver: train a ~100M-parameter LM with the production TL step.

A thin shim over ``repro.launch.engine.Engine``: the model is a scaled-down
llama-family config (deepseek-7b family), the data pipeline is Algorithm 1's
virtual-batch loader over 8 node shards, and the engine drives the pjit TL
step (remat-from-X^(1), node-axis gradient aggregation, ``train_shardings``
+ donation, 2-deep host->device batch prefetch) — the same code path the
512-chip dry-run lowers and ``launch/train.py`` serves from the CLI.

    PYTHONPATH=src python examples/train_tl_100m.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_tl_100m.py --tiny     # CI-sized
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                 synthetic_corpus)
from repro.launch.engine import Engine
from repro.launch.mesh import resolve_mesh
from repro.models import build_model
from repro.optim import adamw, warmup_cosine


def config_100m():
    base = get_config("deepseek-7b")
    return dataclasses.replace(
        base, name="deepseek-100m", n_layers=8, d_model=640, n_heads=10,
        n_kv_heads=10, head_dim=64, d_ff=1792, vocab_size=32000)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "host", "production"])
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false",
                    default=True)
    ap.add_argument("--ckpt", default="/tmp/tl_100m_ckpt")
    args = ap.parse_args(argv)

    cfg = get_config("deepseek-7b", reduced=True) if args.tiny else config_100m()
    if args.tiny:
        args.steps = min(args.steps, 20)
    model = build_model(cfg)
    mesh = resolve_mesh(args.mesh)
    shape = InputShape("train_100m", args.seq, args.batch, "train")
    opt = adamw(warmup_cosine(3e-4, 20, args.steps), clip_norm=1.0)

    engine = Engine(model, cfg, opt, mesh, shape,
                    pipeline=args.pipeline, log_every=10)
    engine.init(jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {engine.n_params()/1e6:.1f}M params, "
          f"{args.nodes} nodes, batch {args.batch}, seq {args.seq}, "
          f"mesh {args.mesh}{mesh.devices.shape}")

    docs = synthetic_corpus(args.nodes * 128, args.seq, cfg.vocab_size, seed=1)
    shards = shard_corpus(docs, args.nodes)
    loader = VirtualBatchLoader(shards, args.batch, seed=0)

    result = engine.run(loader, steps=args.steps)
    losses = result.losses
    tok_s = args.batch * args.seq * result.steps / result.wall_s
    print(f"loss: {np.mean(losses[:10]):.4f} -> {np.mean(losses[-10:]):.4f}"
          f"  ({result.steps_per_s:.2f} steps/s, {tok_s:.0f} tok/s)")
    save_checkpoint(args.ckpt, args.steps, {"params": result.params})
    print("checkpoint saved to", args.ckpt)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not improve"


if __name__ == "__main__":
    main()
