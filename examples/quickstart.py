"""Quickstart: Traversal Learning in ~60 lines.

Four nodes hold private shards; the orchestrator trains a classifier over
them WITHOUT seeing raw data, and the result matches centralized training
exactly (the paper's losslessness claim).

This drives the protocol simulator through the unified training engine
(``repro.launch.engine.Engine`` in ``mode="sim"``) — the same driver API
that runs the sharded pjit path in ``launch/train.py``; ``pipeline=True``
would route epochs through the double-buffered visit-producer /
BP-consumer engine instead of the serial loop (identical parameters either
way).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.configs.paper_models import DATRET
from repro.core import Transport
from repro.core.baselines import ShardData, evaluate, train_cl
from repro.data.datasets import shard_noniid, tabular
from repro.launch.engine import Engine
from repro.models.small import SmallModel
from repro.optim import sgd

import jax


def main():
    # a 4-class tabular task, split non-IID across 4 nodes
    ds = tabular(n=1200, d=32, n_classes=4, seed=0, margin=2.0, noise=0.8)
    train, test = ds.split(0.8)
    shards = shard_noniid(train, n_nodes=4, alpha=0.3, seed=1)
    cfg = dataclasses.replace(DATRET, n_classes=ds.n_classes)
    model = SmallModel(cfg)

    # --- Traversal Learning: FP on nodes, BP on the orchestrator ---------
    transport = Transport()
    engine = Engine(model, cfg, sgd(0.05), mode="sim", pipeline=False,
                    batch_size=32, seed=0, transport=transport)
    result = engine.run(shards, epochs=4)
    for epoch, stats in enumerate(result.epoch_stats):
        print(f"epoch {epoch}: loss {np.mean([s.loss for s in stats]):.4f} "
              f"acc {np.mean([s.acc for s in stats]):.3f} "
              f"eq12-consistency {max(s.grad_consistency for s in stats):.2e}")

    acc_tl = evaluate(model, result.params, test.x, test.y)["acc"]

    # --- centralized reference (privacy-violating upper bound) -----------
    sdata = [ShardData(jax.numpy.asarray(s.x), jax.numpy.asarray(s.y))
             for s in shards]
    p_cl = train_cl(model, sdata, sgd(0.05), key=jax.random.PRNGKey(0),
                    epochs=4, batch_size=32)
    acc_cl = evaluate(model, p_cl, test.x, test.y)["acc"]

    mb = transport.total_bytes / 1e6
    print(f"\nTL test acc  {acc_tl:.3f}")
    print(f"CL test acc  {acc_cl:.3f}   (TL is lossless: same data, same "
          f"quality, raw data never moved)")
    print(f"TL communication: {mb:.1f} MB "
          f"({transport.n_messages} messages, simulated "
          f"{transport.clock_s:.2f}s on a 1 Gb/s WAN)")


if __name__ == "__main__":
    main()
