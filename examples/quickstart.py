"""Quickstart: Traversal Learning in ~60 lines.

Four nodes hold private shards; the orchestrator trains a classifier over
them WITHOUT seeing raw data, and the result matches centralized training
exactly (the paper's losslessness claim).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

import dataclasses

from repro.configs.paper_models import DATRET
from repro.core import TLNode, TLOrchestrator, Transport
from repro.core.baselines import ShardData, evaluate, train_cl
from repro.data.datasets import shard_noniid, tabular
from repro.models.small import SmallModel
from repro.optim import sgd


def main():
    # a 4-class tabular task, split non-IID across 4 nodes
    ds = tabular(n=1200, d=32, n_classes=4, seed=0, margin=2.0, noise=0.8)
    train, test = ds.split(0.8)
    shards = shard_noniid(train, n_nodes=4, alpha=0.3, seed=1)
    model = SmallModel(dataclasses.replace(DATRET, n_classes=ds.n_classes))

    # --- Traversal Learning: FP on nodes, BP on the orchestrator ---------
    transport = Transport()
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), transport,
                          batch_size=32, seed=0)
    orch.initialize(jax.random.PRNGKey(0))
    for epoch in range(4):
        stats = orch.train_epoch()
        print(f"epoch {epoch}: loss {np.mean([s.loss for s in stats]):.4f} "
              f"acc {np.mean([s.acc for s in stats]):.3f} "
              f"eq12-consistency {max(s.grad_consistency for s in stats):.2e}")

    acc_tl = evaluate(model, orch.params, test.x, test.y)["acc"]

    # --- centralized reference (privacy-violating upper bound) -----------
    sdata = [ShardData(jax.numpy.asarray(s.x), jax.numpy.asarray(s.y))
             for s in shards]
    p_cl = train_cl(model, sdata, sgd(0.05), key=jax.random.PRNGKey(0),
                    epochs=4, batch_size=32)
    acc_cl = evaluate(model, p_cl, test.x, test.y)["acc"]

    mb = transport.total_bytes / 1e6
    print(f"\nTL test acc  {acc_tl:.3f}")
    print(f"CL test acc  {acc_cl:.3f}   (TL is lossless: same data, same "
          f"quality, raw data never moved)")
    print(f"TL communication: {mb:.1f} MB "
          f"({transport.n_messages} messages, simulated "
          f"{transport.clock_s:.2f}s on a 1 Gb/s WAN)")


if __name__ == "__main__":
    main()
