"""Compare TL against CL / FL / SL / SL+ / SFL on a non-IID task — the
paper's Table 1 experiment in miniature, with communication accounting.

    PYTHONPATH=src python examples/compare_methods.py
"""
import jax

import dataclasses

from repro.configs.paper_models import DATRET
from repro.core import PlanSpec, TLNode, TLOrchestrator, Transport
from repro.core import baselines as B
from repro.data.datasets import shard_noniid, tabular
from repro.models.small import SmallModel
from repro.optim import sgd

EPOCHS, BATCH, LR, NODES = 4, 32, 0.05, 4


def main():
    ds = tabular(1200, 32, 4, seed=0, margin=2.0, noise=0.8)
    train, test = ds.split(0.8)
    shards = shard_noniid(train, NODES, alpha=0.25, seed=1)
    sdata = [B.ShardData(jax.numpy.asarray(s.x), jax.numpy.asarray(s.y))
             for s in shards]
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    key = jax.random.PRNGKey(0)
    rows = []

    p = B.train_cl(model, sdata, sgd(LR), key=key, epochs=EPOCHS,
                   batch_size=BATCH)
    rows.append(("CL", B.evaluate(model, p, test.x, test.y), 0))

    tr = Transport()
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    # paper-faithful: redistribute the model every virtual batch (Alg. 2);
    # cache_model_per_epoch=True is the §5.2 bandwidth knob but introduces
    # within-epoch staleness and is NOT lossless
    orch = TLOrchestrator(model, nodes, sgd(LR), tr, batch_size=BATCH,
                          plan=PlanSpec(seed=0), check_consistency=False)
    orch.initialize(key)
    for _ in range(EPOCHS):
        orch.train_epoch()
    rows.append(("TL", B.evaluate(model, orch.params, test.x, test.y),
                 tr.total_bytes))

    tr = Transport()
    p = B.train_fl(model, sdata, sgd(LR), key=key, rounds=EPOCHS,
                   local_epochs=1, batch_size=BATCH, transport=tr)
    rows.append(("FL", B.evaluate(model, p, test.x, test.y), tr.total_bytes))

    tr = Transport()
    p = B.train_sl(model, sdata, sgd(LR), key=key, rounds=EPOCHS,
                   batch_size=BATCH, transport=tr)
    rows.append(("SL", B.evaluate(model, p, test.x, test.y), tr.total_bytes))

    tr = Transport()
    p = B.train_sl(model, sdata, sgd(LR), key=key, rounds=EPOCHS,
                   batch_size=BATCH, transport=tr, no_label_sharing=True)
    rows.append(("SL+", B.evaluate(model, p, test.x, test.y), tr.total_bytes))

    tr = Transport()
    p = B.train_sfl(model, sdata, sgd(LR), key=key, rounds=EPOCHS,
                    batch_size=BATCH, transport=tr)
    rows.append(("SFL", B.evaluate(model, p, test.x, test.y), tr.total_bytes))

    print(f"\n{'method':6s} {'acc':>7s} {'macroF1':>8s} {'MB moved':>9s}")
    for name, m, nbytes in rows:
        print(f"{name:6s} {m['acc']:7.3f} {m['macro_f1']:8.3f} "
              f"{nbytes/1e6:9.1f}")


if __name__ == "__main__":
    main()
