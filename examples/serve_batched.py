"""Continuous-batching demo: N ragged prompts arrive staggered over time and
flow through the serving engine (``repro.serve.ServeEngine``) — admission
queue, paged KV cache, batched decode, eviction on length/EOS — with
per-request latency printed at the end.

Unlike a static batch, nothing waits for stragglers: request 3 can be
admitted while requests 0-2 are mid-decode, and a finished request frees its
pages immediately for the next arrival.  Every emitted stream is
token-identical to decoding that prompt alone (``tests/test_serve.py``).

    PYTHONPATH=src python examples/serve_batched.py [--arch deepseek-7b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b",
                    help="servable arch (decoder-only, full/mla attention)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--attention", choices=["paged", "dense"],
                    default="paged")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="offered load, requests/s (Poisson arrivals)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    lengths = rng.integers(8, args.max_prompt + 1, args.requests)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, l)
                    .astype(np.int32),
                    max_new_tokens=args.gen)
            for i, l in enumerate(lengths)]
    print(f"serving {args.requests} requests, prompt lens {lengths.tolist()},"
          f" arrivals {[round(a, 2) for a in arrivals.tolist()]} s")

    # one clock everywhere: request arrivals and the engine's token
    # timestamps must share an epoch for the latency math below
    eng = ServeEngine(model, cfg, params, num_pages=128, page_size=8,
                      max_slots=8, max_len=args.max_prompt + args.gen,
                      attention=args.attention, clock=time.perf_counter)
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or not eng.idle:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            reqs[i].arrival = t0 + arrivals[i]
            eng.submit(reqs[i])
            i += 1
        if eng.idle:                     # nothing active: wait for an arrival
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.025))
            continue
        eng.step()
    makespan = time.perf_counter() - t0

    n_tok = 0
    for r in sorted(eng.results.values(), key=lambda r: r.rid):
        n_tok += len(r.tokens)
        ttft = (r.token_times[0] - r.arrival) * 1e3
        total = (r.token_times[-1] - r.arrival) * 1e3
        print(f"req {r.rid} (len {r.prompt_len:2d}) [{r.finish_reason}] "
              f"ttft {ttft:6.1f} ms, total {total:7.1f} ms: "
              f"{r.tokens[:6]}{'...' if len(r.tokens) > 6 else ''}")
    print(f"{n_tok} tokens in {makespan:.2f} s "
          f"({n_tok / makespan:.1f} tok/s, attention={args.attention})")


if __name__ == "__main__":
    main()
