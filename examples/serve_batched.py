"""Batched serving example: a request queue with mixed prompt lengths served
through prefill + batched decode (the serve_step the decode dry-runs lower).

    PYTHONPATH=src python examples/serve_batched.py [--arch starcoder2-3b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.tl_step import make_serve_step
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    # a queue of requests with different prompt lengths
    rng = np.random.default_rng(0)
    lengths = rng.integers(8, args.max_prompt + 1, args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, l) for l in lengths]
    print(f"serving {args.requests} requests, prompt lens {lengths.tolist()}")

    # left-pad into one batch (padding attends nothing thanks to causal mask
    # + position offsets: we right-align prompts so decode starts together)
    P = max(lengths)
    B = len(prompts)
    batch_tokens = np.zeros((B, P), np.int32)
    for i, p in enumerate(prompts):
        batch_tokens[i, P - len(p):] = p

    cache = model.init_cache(B, max_len=P + args.gen)
    t0 = time.time()
    logits, cache = model.prefill(params, cache, jnp.asarray(batch_tokens))
    t_prefill = time.time() - t0

    step_fn = jax.jit(make_serve_step(model, cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for t in range(args.gen - 1):
        logits, cache = step_fn(params, cache, tok,
                                jnp.asarray(P + t, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    t_decode = time.time() - t0
    gen = np.asarray(jnp.stack(out, 1))
    for i in range(B):
        print(f"req {i} (len {lengths[i]:2d}): {gen[i].tolist()}")
    print(f"prefill {t_prefill*1e3:.0f} ms, decode "
          f"{B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s")


if __name__ == "__main__":
    main()
