"""Gate a BENCH_*.json artifact against its committed schema.

The benchmark artifacts are the repo's machine-readable perf trail; CI runs
the smoke benchmark on every push and uploads the artifact, but an artifact
whose *shape* silently changed (a renamed column, a dropped key) would rot
every downstream diff.  This tool extracts the artifact's schema — the set
of key paths with leaf type classes, with numeric dict keys (the per-node-
count tables) wildcarded to ``*`` — and fails if it drifts from the
committed schema file.

    python benchmarks/check_artifact_schema.py BENCH_tl_step_smoke.json \
        --schema benchmarks/schemas/tl_step_smoke.schema.json

``--write`` regenerates the schema file from the artifact (the one
legitimate way to change the contract — the diff then shows up in review).
"""
from __future__ import annotations

import argparse
import json
import sys


def _leaf_type(v) -> str:
    if isinstance(v, bool):                 # before int: bool <: int
        return "bool"
    if isinstance(v, (int, float)):
        return "number"
    if isinstance(v, str):
        return "string"
    if v is None:
        return "null"
    if isinstance(v, list):
        return "array"
    return type(v).__name__


def _is_numeric_key(k: str) -> bool:
    try:
        float(k)
        return True
    except (TypeError, ValueError):
        return False


def extract_schema(obj, prefix: str = "") -> set:
    """Key paths + leaf type classes, numeric dict keys wildcarded.

    ``{"nodes": {"2": {"x": 1.0}, "4": {"x": 2.0}}}`` extracts to
    ``{"nodes.*.x:number"}`` — the per-node-count columns are one schema
    entry regardless of which node counts a given run swept."""
    if isinstance(obj, dict):
        out = set()
        for k, v in obj.items():
            part = "*" if _is_numeric_key(k) else str(k)
            out |= extract_schema(v, f"{prefix}.{part}" if prefix else part)
        return out
    return {f"{prefix}:{_leaf_type(obj)}"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help="BENCH_*.json artifact to validate")
    ap.add_argument("--schema", required=True,
                    help="committed schema file (sorted key-path list)")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the schema file from the artifact "
                         "instead of validating")
    args = ap.parse_args(argv)

    with open(args.artifact) as f:
        artifact = json.load(f)
    got = extract_schema(artifact)

    if args.write:
        with open(args.schema, "w") as f:
            json.dump({"artifact": args.artifact.split("/")[-1],
                       "paths": sorted(got)}, f, indent=1)
            f.write("\n")
        print(f"wrote {len(got)} schema paths to {args.schema}")
        return 0

    with open(args.schema) as f:
        want = set(json.load(f)["paths"])
    missing, unexpected = sorted(want - got), sorted(got - want)
    if missing or unexpected:
        print(f"SCHEMA DRIFT in {args.artifact}:")
        for p in missing:
            print(f"  missing:    {p}")
        for p in unexpected:
            print(f"  unexpected: {p}")
        print("(intentional? regenerate with --write and commit the diff)")
        return 1
    print(f"schema OK: {len(got)} paths match {args.schema}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
