"""Roofline report: aggregates dry-run artifacts into the §Roofline table.

Reads experiments/artifacts/*.json (produced by repro.launch.run_dryruns)
and prints one row per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, and the MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

import glob
import json
import os
import time

ARTIFACT_DIR = os.environ.get("REPRO_ARTIFACTS", "experiments/artifacts")


def load_artifacts(tag=None):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if tag and not path.endswith(f"__{tag}.json"):
            continue
        rows.append(art)
    return rows


def main():
    t0 = time.time()
    rows = load_artifacts(tag="baseline")
    if not rows:
        print("roofline/no_artifacts,0,run repro.launch.run_dryruns first")
        return []
    n_ok = n_skip = n_bad = 0
    for a in rows:
        us = (time.time() - t0) * 1e6
        key = f"{a['arch']}/{a['shape']}/{a['mesh']}"
        if a["status"] == "ok":
            n_ok += 1
            derived = (f"C={a['t_compute']:.3e}s;M={a['t_memory']:.3e}s;"
                       f"N={a['t_collective']:.3e}s;dom={a['bottleneck']};"
                       f"useful={a['useful_flops_ratio']:.3f};"
                       f"mem/chip={a['peak_memory_per_chip']/2**30:.1f}GiB")
        elif a["status"] == "skipped":
            n_skip += 1
            derived = "designed-skip(full-attention long-context)"
        else:
            n_bad += 1
            derived = a["status"]
        print(f"roofline/{key},{us:.0f},{derived}")
    print(f"roofline/summary,0,ok={n_ok};skipped={n_skip};failed={n_bad}")
    return rows


if __name__ == "__main__":
    main()
