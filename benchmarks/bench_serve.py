"""Open-loop Poisson-arrival serving benchmark (``BENCH_serve.json``).

Drives the continuous-batching engine (``repro.serve.ServeEngine``) with an
**open-loop** arrival process: request arrival times are drawn from a
Poisson process at a fixed offered load (req/s) *before* serving starts, so
a slow server cannot throttle its own arrivals — queueing delay shows up in
the latency percentiles instead of disappearing, which is the honest way to
measure a serving system.

Per offered-load point it records throughput (generated tokens / makespan),
p50/p99 **per-token latency** (inter-token gaps within each request), and
p50 TTFT (admission → first token), for one dense-attention arch
(deepseek-7b) and one MLA+MoE arch (deepseek-v2-236b), both reduced.

Two robustness columns ride the same artifact (ISSUE 9):

* ``overload`` — the same open loop pushed to ~2x the measured saturating
  request rate with per-request SLO deadlines, shedding **on vs off**:
  goodput (tokens of deadline-met requests / makespan), p99 per-token
  latency, and shed/abort counts.  Shedding converts a collapsed queue
  into explicit refusals and keeps the survivors' latency bounded.
* ``recovery`` — the scripted chaos drill (decode-step crash under
  supervision): detect → rebuild → re-prefill → first-token wall costs
  and the token-identity verdict against the fault-free oracle.

Numbers on this container are CPU (Pallas kernels in interpret mode) — the
load points are chosen to show the under-load → saturation transition, not
absolute TPU throughput.  Smoke mode (CI: ``benchmarks/run.py --only
serve_smoke``) runs the same grid smaller; the artifact schema is identical
(load keys are numeric and wildcarded by ``check_artifact_schema.py``).
"""
from __future__ import annotations

import subprocess
import time

import numpy as np

ARCHS = ["deepseek-7b", "deepseek-v2-236b"]
PROMPT_LENS = [8, 16]          # small fixed set bounds prefill compilations
PAGE_SIZE = 8
NUM_PAGES = 128
MAX_SLOTS = 8


def _git_rev() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:  # noqa: BLE001
        return "unknown"


def _build(arch):
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _make_requests(cfg, n, gen_len, rate, seed):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = []
    for i in range(n):
        P = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(0, cfg.vocab_size, size=(P,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gen_len,
                            temperature=0.0, seed=i))
    return arrivals, reqs


def _run_load(model, cfg, params, *, rate, n_requests, gen_len, seed,
              slo_s=None, shedding=True):
    """One offered-load point: open-loop wall-clock drive.

    With ``slo_s`` set, every request carries an absolute deadline
    (arrival + slo_s) in the engine's clock domain (perf_counter — the
    same clock the open loop schedules arrivals on) and the overload
    metrics (goodput, shed/abort counts) are included."""
    from repro.serve import ServeEngine
    eng = ServeEngine(model, cfg, params, num_pages=NUM_PAGES,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                      max_len=max(PROMPT_LENS) + gen_len, attention="paged",
                      decode_priority=1, seed=0, shedding=shedding,
                      clock=time.perf_counter)
    arrivals, reqs = _make_requests(cfg, n_requests, gen_len, rate, seed)

    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or not eng.idle:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            reqs[i].arrival = t0 + arrivals[i]
            if slo_s is not None:
                reqs[i].deadline = t0 + arrivals[i] + slo_s
            eng.submit(reqs[i])
            i += 1
        if eng.idle:                      # wait for the next open-loop arrival
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.025))
            continue
        eng.step()
    makespan = time.perf_counter() - t0

    gaps, ttfts, n_tokens = [], [], 0
    good_tokens, n_met = 0, 0
    for r in eng.results.values():
        ts = r.token_times
        n_tokens += len(r.tokens)
        if ts:
            ttfts.append(ts[0] - r.admitted)
            gaps.extend(float(b - a) for a, b in zip(ts, ts[1:]))
        req = reqs[r.rid]
        if (r.finish_reason in ("eos", "length")
                and (req.deadline is None or ts[-1] <= req.deadline)):
            good_tokens += len(r.tokens)
            n_met += 1
    gaps = gaps or [0.0]
    ttfts = ttfts or [0.0]
    point = {
        "offered_load_rps": float(rate),
        "n_requests": n_requests,
        "tokens": n_tokens,
        "makespan_s": round(makespan, 4),
        "tokens_per_s": round(n_tokens / makespan, 2),
        "p50_token_latency_ms": round(float(np.percentile(gaps, 50)) * 1e3, 3),
        "p99_token_latency_ms": round(float(np.percentile(gaps, 99)) * 1e3, 3),
        "ttft_p50_ms": round(float(np.percentile(ttfts, 50)) * 1e3, 3),
    }
    if slo_s is not None:
        stats = eng.stats()
        point.update({
            "goodput_tokens_per_s": round(good_tokens / makespan, 2),
            "n_deadline_met": n_met,
            "n_shed": stats["n_shed"],
            "n_deadline_aborts": stats["n_deadline_aborts"],
        })
    return point


def _bench_recovery(model, cfg, params, *, gen_len, n_requests=4):
    """The chaos drill as a benchmark: a scripted decode-step crash under
    supervision.  Reports the detect/rebuild/re-prefill/first-token wall
    costs and verifies token identity against the fault-free oracle."""
    import jax.numpy as jnp
    import repro.launch.serve as launch_serve
    from repro.serve import (CRASH, Request, ServeDrill, ServeEngine,
                             ServeFaultSpec)
    P = PROMPT_LENS[0]
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(n_requests, P)).astype(np.int32)
    eng = ServeEngine(model, cfg, params, num_pages=NUM_PAGES,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                      max_len=P + gen_len, attention="paged",
                      faults=ServeFaultSpec(drills=(ServeDrill(CRASH, 2),)))
    res = eng.serve([Request(rid=i, prompt=prompts[i],
                             max_new_tokens=gen_len, seed=i)
                     for i in range(n_requests)])
    oracle = np.asarray(launch_serve.generate(model, cfg, params,
                                              jnp.asarray(prompts), gen_len))
    identical = all(res[i].tokens == oracle[i].tolist()
                    for i in range(n_requests))
    rep = eng.recoveries[0].as_dict()
    return {
        "drill": "crash:2",
        "n_requests": n_requests,
        "n_survivors": rep["n_survivors"],
        "token_identical": bool(identical),
        "detect_ms": round(rep["detect_s"] * 1e3, 2),
        "rebuild_ms": round(rep["rebuild_s"] * 1e3, 2),
        "reprefill_ms": round(rep["reprefill_s"] * 1e3, 2),
        "first_token_ms": round(rep["first_token_s"] * 1e3, 2),
        "total_ms": round(rep["total_s"] * 1e3, 2),
    }


def bench_arch(arch, *, loads, n_requests, gen_len):
    cfg, model, params = _build(arch)
    # warm the jit caches (prefill per prompt length, every power-of-two
    # decode bucket, sampler) so load point 1 doesn't pay compile time as
    # fake queueing delay — MAX_SLOTS simultaneous requests sweep the active
    # count through 1..MAX_SLOTS as admissions trickle in
    from repro.serve import Request, ServeEngine
    warm = ServeEngine(model, cfg, params, num_pages=NUM_PAGES,
                       page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                       max_len=max(PROMPT_LENS) + gen_len)
    warm.serve([Request(rid=i, prompt=np.full((PROMPT_LENS[i % 2],), 1,
                                              np.int32),
                        max_new_tokens=MAX_SLOTS)
                for i in range(MAX_SLOTS)])
    out = {"attention": "paged", "gen_len": gen_len, "loads": {}}
    for li, rate in enumerate(loads):
        point = _run_load(model, cfg, params, rate=rate,
                          n_requests=n_requests, gen_len=gen_len,
                          seed=1000 + li)
        out["loads"][str(rate)] = point
        print(f"bench_serve/{arch}@{rate}rps,"
              f"{point['p50_token_latency_ms'] * 1e3:.0f},"
              f"{point['tokens_per_s']}tok/s")

    # overload column: ~2x the measured saturating request rate, with an
    # SLO wide enough that an unloaded request clears it comfortably
    sat_rps = max(p["tokens_per_s"] for p in out["loads"].values()) / gen_len
    base = out["loads"][str(loads[0])]
    slo_s = (base["ttft_p50_ms"]
             + 4.0 * gen_len * base["p50_token_latency_ms"]) / 1e3
    over_rate = round(2.0 * sat_rps, 3)
    out["overload"] = {
        "offered_load_rps": over_rate,
        "slo_ms": round(slo_s * 1e3, 1),
        "shed_on": _run_load(model, cfg, params, rate=over_rate,
                             n_requests=n_requests, gen_len=gen_len,
                             seed=2000, slo_s=slo_s, shedding=True),
        "shed_off": _run_load(model, cfg, params, rate=over_rate,
                              n_requests=n_requests, gen_len=gen_len,
                              seed=2000, slo_s=slo_s, shedding=False),
    }
    for leg in ("shed_on", "shed_off"):
        p = out["overload"][leg]
        print(f"bench_serve/{arch}/overload/{leg}@{over_rate}rps,"
              f"goodput={p['goodput_tokens_per_s']}tok/s,"
              f"shed={p['n_shed']}+{p['n_deadline_aborts']}")

    out["recovery"] = _bench_recovery(model, cfg, params, gen_len=gen_len)
    print(f"bench_serve/{arch}/recovery,"
          f"total={out['recovery']['total_ms']}ms,"
          f"identical={out['recovery']['token_identical']}")
    return out


def main(smoke: bool = False) -> dict:
    import jax
    loads = [4.0, 16.0] if smoke else [2.0, 8.0, 32.0]
    n_requests = 5 if smoke else 16
    gen_len = 8 if smoke else 24
    result = {
        "benchmark": "serve_smoke" if smoke else "serve",
        "git_rev": _git_rev(),
        "backend": jax.default_backend(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "page_size": PAGE_SIZE,
        "num_pages": NUM_PAGES,
        "max_slots": MAX_SLOTS,
        "archs": {arch: bench_arch(arch, loads=loads, n_requests=n_requests,
                                   gen_len=gen_len)
                  for arch in ARCHS},
    }
    return result


if __name__ == "__main__":
    import json
    import sys
    art = main(smoke="--smoke" in sys.argv)
    print(json.dumps(art, indent=1))
