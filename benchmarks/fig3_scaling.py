"""Paper Figure 3 analogue: runtime scalability vs number of nodes.

Sweeps n_nodes over the analytic runtime model (eqs. 15–19) and over the
transport-simulated TL protocol, emitting per-method runtime curves.
Validates the paper's claims: TL flattest, SL/SL+ blow up linearly.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs.paper_models import DATRET
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.runtime_model import (WorkloadSpec, runtime_fl, runtime_sfl,
                                      runtime_sl, runtime_slp, runtime_tl)
from repro.core.transport import NetworkModel, Transport
from repro.data.datasets import shard_iid, tabular
from repro.models.small import SmallModel
from repro.optim import sgd

NODES = (5, 10, 20, 40, 80)


def analytic_curves():
    base = WorkloadSpec(
        n_nodes=20, samples_per_node=500, batch_size=50,
        model_bytes=45e6, first_layer_bytes_per_sample=64 * 28 * 28 * 4,
        logits_bytes_per_sample=40, first_layer_param_bytes=64 * 9 * 4,
        flops_per_sample_fwd=1.8e9, flops_per_sample_bwd=3.6e9,
        client_flops_per_s=5e12, server_flops_per_s=1e14)
    curves = {m: [] for m in ("FL", "SL", "SL+", "SFL", "TL")}
    for n in NODES:
        spec = dataclasses.replace(base, n_nodes=n)
        curves["FL"].append(runtime_fl(spec))
        curves["SL"].append(runtime_sl(spec))
        curves["SL+"].append(runtime_slp(spec))
        curves["SFL"].append(runtime_sfl(spec))
        curves["TL"].append(runtime_tl(spec, cache_model=True))
    return curves


def simulated_tl_curve(nodes=(2, 4, 8)):
    out = []
    for n in nodes:
        ds = tabular(n * 40, 32, 4, seed=0)
        shards = shard_iid(ds, n, seed=0)
        model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
        tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=1e9 / 8,
                                            rtt_s=0.02))
        tl_nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
        orch = TLOrchestrator(model, tl_nodes, sgd(0.05), tr, batch_size=40,
                              plan=PlanSpec(seed=0), check_consistency=False,
                              cache_model_per_epoch=True)
        orch.initialize(jax.random.PRNGKey(0))
        orch.train_epoch()
        out.append((n, tr.clock_s, tr.total_bytes))
    return out


def main():
    t0 = time.time()
    curves = analytic_curves()
    for m, vals in curves.items():
        for n, v in zip(NODES, vals):
            print(f"fig3/analytic/{m}/nodes{n},{(time.time()-t0)*1e6:.0f},{v:.2f}")
    # claims: TL flattest; SL linear in nodes
    tl_growth = curves["TL"][-1] / curves["TL"][0]
    sl_growth = curves["SL"][-1] / curves["SL"][0]
    assert tl_growth < 2.0 < sl_growth
    sim = simulated_tl_curve()
    for n, clock, nbytes in sim:
        print(f"fig3/simulated_tl/nodes{n},{(time.time()-t0)*1e6:.0f},"
              f"{clock:.4f}s/{nbytes}B")
    return curves


if __name__ == "__main__":
    main()
