"""Paper Table 1 analogue: quality of CL / TL / FL / SL / SL+ / SFL across
dataset families (IID, non-IID, imbalanced-binary, text), n runs each.

Absolute numbers differ from the paper (synthetic data, reduced models, CPU
budget); the claim validated is the ORDERING: TL ≈ CL, both above FL/SL/SFL
on heterogeneous data.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs.paper_models import DATRET, TINY_TRANSFORMER
from repro.core import baselines as B
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.transport import Transport
from repro.data.datasets import (imbalanced_binary, shard_cluster, shard_iid,
                                 shard_noniid, tabular, text_tokens)
from repro.models.small import SmallModel
from repro.optim import sgd

N_NODES = 4
SEEDS = 3
LR = 0.05


def _train_tl(model, shards, key, epochs, batch):
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(LR), Transport(),
                          batch_size=batch, plan=PlanSpec(seed=0),
                          check_consistency=False)
    orch.initialize(key)
    for _ in range(epochs):
        orch.train_epoch()
    return orch.params


def run_family(name, make_ds, shard_fn, model_cfg, metric, *, epochs=3,
               batch=32, seeds=SEEDS):
    rows = {}
    for method in ("CL", "TL", "FL", "SL", "SL+", "SFL"):
        vals = []
        for seed in range(seeds):
            ds = make_ds(seed)
            train, test = ds.split(0.8, seed=seed)
            shards = shard_fn(train, seed)
            sdata = [B.ShardData(jax.numpy.asarray(s.x),
                                 jax.numpy.asarray(s.y)) for s in shards]
            model = SmallModel(dataclasses.replace(
                model_cfg, n_classes=ds.n_classes))
            key = jax.random.PRNGKey(seed)
            t0 = time.time()
            if method == "CL":
                p = B.train_cl(model, sdata, sgd(LR), key=key, epochs=epochs,
                               batch_size=batch, seed=seed)
            elif method == "TL":
                p = _train_tl(model, shards, key, epochs, batch)
            elif method == "FL":
                p = B.train_fl(model, sdata, sgd(LR), key=key, rounds=epochs,
                               local_epochs=1, batch_size=batch, seed=seed)
            elif method == "SL":
                p = B.train_sl(model, sdata, sgd(LR), key=key, rounds=epochs,
                               batch_size=batch, seed=seed)
            elif method == "SL+":
                p = B.train_sl(model, sdata, sgd(LR), key=key, rounds=epochs,
                               batch_size=batch, seed=seed,
                               no_label_sharing=True)
            else:
                p = B.train_sfl(model, sdata, sgd(LR), key=key, rounds=epochs,
                                batch_size=batch, seed=seed)
            m = B.evaluate(model, p, test.x, test.y)
            vals.append(m.get(metric, m["acc"]))
        rows[method] = (float(np.mean(vals)), float(np.std(vals)))
    return rows


def main(out_rows=None):
    families = [
        ("iid_tabular/acc",
         lambda s: tabular(800, 32, 4, seed=s, margin=2.0, noise=0.8),
         lambda ds, s: shard_iid(ds, N_NODES, seed=s), DATRET, "acc"),
        ("noniid_cluster/macro_f1",
         lambda s: tabular(800, 32, 4, seed=s, margin=2.0, noise=0.8),
         lambda ds, s: shard_noniid(ds, N_NODES, alpha=0.25, seed=s),
         DATRET, "macro_f1"),
        ("imbalanced_binary/auc",
         lambda s: imbalanced_binary(1200, 32, pos_frac=0.15, seed=s),
         lambda ds, s: shard_cluster(ds, N_NODES, seed=s), DATRET, "auc"),
        ("text/auc",
         lambda s: text_tokens(600, seq_len=32, vocab=256, seed=s),
         lambda ds, s: shard_iid(ds, N_NODES, seed=s),
         TINY_TRANSFORMER, "auc"),
    ]
    results = {}
    for name, mk, sh, cfg, metric in families:
        t0 = time.time()
        rows = run_family(name, mk, sh, cfg, metric)
        results[name] = rows
        us = (time.time() - t0) * 1e6
        for method, (mean, std) in rows.items():
            derived = f"{mean:.4f}+-{std:.4f}"
            print(f"table1/{name}/{method},{us/6:.0f},{derived}")
            if out_rows is not None:
                out_rows.append((name, method, mean, std))
    return results


if __name__ == "__main__":
    main()
