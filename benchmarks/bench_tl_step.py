"""TL step-time benchmark: eager reference vs fused vs pipelined hot path.

Measures steps/sec of the protocol simulator's full TL round (model
redistribution + node visits + centralized BP + update) at 2/4/8 simulated
nodes, for

* ``eager`` — the seed's op-by-op path: unjitted node visits, per-node
  ``.at[].set`` scatters, an un-jitted tail vjp per virtual batch, host
  syncs inside every visit;
* ``fused`` — jitted node visits with device-resident stats, one batched
  scatter reassembly, and a single compiled (donated) vjp+update step;
* ``pipelined`` — the fused path driven by the double-buffered epoch engine
  (``repro.core.pipeline``): batch k+1's visits produced while batch k's
  centralized BP consumes;
* ``reassembly`` — the fused path's virtual-batch reassembly strategy:
  ``xla`` (one generic ``.at[perm].set`` scatter per payload tensor, the
  fused column above) vs ``pallas`` (the fused ``repro.kernels.vb_scatter``
  row-routing kernel — one launch, one HBM pass).  On this CPU container
  the kernel runs in interpret mode, so the wall-clock column is a
  correctness-under-load signal, not the TPU speedup; the HBM-byte claim
  is asserted analytically (``predict_reassembly_hbm_bytes`` + the HLO
  scatter accounting in ``tests/test_analysis.py``).

Pipelining is a *clock* optimization in the protocol simulator, so besides
wall-clock steps/sec the benchmark runs a simulated-time epoch (nonzero node
compute + centralized-BP cost on a WAN network model) serial vs pipelined
and records ``Transport.clock_s`` for each — the measurable counterpart of
runtime_model's eq. 19 pipelined form.  The clock columns are the
headline signal: the steps/sec columns share one process's executable
caches (later configurations run warmer), so cross-column wall-clock
ratios carry cache noise the simulated clock does not.

Since PR 3 the full run also measures the *production* path (subprocess
with 8 forced host devices, the (4, 2) host mesh):

* ``production_dryrun`` — the pjit TL step exactly as ``repro.launch.
  engine`` jits it (train_shardings in/out, remat-from-X^(1)) at a scaled
  production shape: compile time, measured CPU step time, and the
  roofline-projected v5e step time from the compiled HLO's FLOPs / HBM /
  collective bytes (the open ROADMAP "production-shape dryrun" column);
* ``engine_clock`` — serial (strictly batch-serial, the historical driver
  semantics) vs pipelined (2-deep host->device prefetch) engine wall-clock
  over the same compiled step at 2/4/8 logical nodes — the device-path
  counterpart of the simulator's ``clock_s`` columns;
* ``elastic_recovery`` — the elastic engine's full detect -> reshrink ->
  restore -> re-jit -> replay recovery wall-clock (a scripted chip kill at
  step 3) at 2/4/8 simulated devices, at rollback depth 1 (``ckpt_every=2``)
  vs depth 3 (``ckpt_every=4``, only the step-0 anchor behind the kill) —
  the measured counterpart of ``runtime_model.recovery_cost``; re-jit for
  the shrunken mesh dominates, replay scales with rollback depth.

The full run also measures the *hierarchy* column: flat vs two-tier
(``HierarchicalOrchestrator``) simulated clock at 64/256/1024 nodes under
a uniform one-batch-per-epoch composition, next to the eq. 19 two-tier
analytic prediction (``runtime_model.runtime_tl(hierarchy=...)``) — the
clock-vs-node-count chart of the hierarchical-TL tentpole.  The 64-node
point runs standalone as ``benchmarks/run.py --only hierarchy_smoke``.

``BENCH_tl_step.json`` at the repo root is the repo's step-time perf
*trajectory*: a list of runs keyed by git rev, appended to (never
overwritten) on each invocation; run via ``benchmarks/run.py`` (smoke) or
directly: ``PYTHONPATH=src python benchmarks/bench_tl_step.py``.
"""
import json
import os
import subprocess
import sys
import textwrap
import time
from typing import Optional

import jax
import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_tl_step.json")

TOTAL_SAMPLES = 512
BATCH_SIZE = 64

# simulated cost model for the clock columns: node FP+local-BP compute per
# sample, and orchestrator centralized-BP per virtual-batch sample
SIM_COMPUTE_S_PER_SAMPLE = 1e-4
SIM_BP_S_PER_SAMPLE = 5e-4

# ---- two-tier hierarchy column (clock vs node count) -----------------------
# 2 samples/node and batch_size = 2·n_nodes give exactly ONE virtual batch
# per epoch in which every node contributes exactly 2 rows — the uniform
# composition runtime_model's two-tier branch assumes, so the analytic
# prediction is byte-exact against the measured transport clock.  rtt=0
# keeps the alignment exact (the same regime the existing eq. 19 alignment
# test pins); the 8 Gb/s link keeps the serialized root merge (one gradient
# pytree per subtree) from drowning the parallel-lane win.
HIER_NODE_COUNTS = (64, 256, 1024)
HIER_SUBTREES = {64: 8, 256: 16, 1024: 32}
HIER_SAMPLES_PER_NODE = 2
HIER_BW = 1e9
HIER_RTT = 0.0


def _git_rev() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              cwd=REPO_ROOT, capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def _build_orchestrator(n_nodes: int, *, fused: bool, pipelined: bool = False,
                        simulate_time: bool = False,
                        reassembly: str = "xla", wire=None):
    from repro.configs.paper_models import DATRET
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.plan import PlanSpec
    from repro.core.transport import Transport
    from repro.models.small import SmallModel
    from repro.optim import sgd

    cfg = DATRET
    model = SmallModel(cfg)
    per_node = TOTAL_SAMPLES // n_nodes
    r = np.random.default_rng(0)
    nodes = [TLNode(i, model,
                    r.normal(size=(per_node,) + cfg.in_shape).astype(np.float32),
                    r.integers(0, cfg.n_classes, per_node),
                    jit_visits=fused)
             for i in range(n_nodes)]
    time_kw = {}
    if simulate_time:
        time_kw = dict(
            compute_time_fn=lambda k: SIM_COMPUTE_S_PER_SAMPLE * k,
            bp_time_fn=lambda n: SIM_BP_S_PER_SAMPLE * n)
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(wire=wire),
                          batch_size=BATCH_SIZE, plan=PlanSpec(seed=0),
                          fused=fused, donate=fused, pipelined=pipelined,
                          reassembly=reassembly, **time_kw)
    orch.initialize(jax.random.PRNGKey(0))
    return orch


# Each epoch reshuffles the traversal plan, so segment lengths — and with
# them the bucket-padded visit shapes and eager pad/slice executables —
# keep producing NEW compilations for the first ~3 epochs before the shape
# space is covered.  A single warmup epoch (the original methodology) puts
# epoch 1's ~84 compiles inside the measured window and understates
# steps/sec by ~10x for whichever configuration runs first in the process.
WARMUP_EPOCHS = 4


def _measure(orch, epochs: int) -> float:
    """Steps/sec over `epochs` epochs after a shape-space-covering warmup."""
    for _ in range(WARMUP_EPOCHS):                         # warmup + compile
        orch.train_epoch()
    jax.block_until_ready(orch.params)
    steps = 0
    t0 = time.perf_counter()
    for _ in range(epochs):
        steps += len(orch.train_epoch())
    jax.block_until_ready(orch.params)
    return steps / (time.perf_counter() - t0)


def _wire_compression(n_nodes: int, epochs: int) -> dict:
    """The compressed-traversal-wire column: steps/s, cumulative visit wire
    bytes, and the measured raw/wire bytes ratio per rung on the fused
    path.  The ratio is the bandwidth headline (the acceptance bar is
    >=3.5x under int8); the steps/s shows the quant/dequant cost on this
    backend.  Model-parameter bytes are identical across rungs by
    construction (the "model" tag never quantizes)."""
    from repro.core.transport import WirePolicy
    col = {}
    for key, pol in (("off", None),
                     ("int8", WirePolicy.visits("int8")),
                     ("fp8_ef", WirePolicy.visits("fp8",
                                                  error_feedback=True))):
        orch = _build_orchestrator(n_nodes, fused=True, wire=pol)
        sps = _measure(orch, epochs)
        tr = orch.transport
        tag = "activations_grads"
        col[key] = {
            "steps_per_s": round(sps, 2),
            "visit_bytes": tr.bytes_sent[tag],
            "bytes_ratio": round(
                tr.raw_bytes[tag] / max(tr.bytes_sent[tag], 1), 2),
        }
    return col


def _build_hier_orchestrator(n_nodes: int, n_subtrees: Optional[int]):
    """Flat (``n_subtrees=None``) or two-tier simulated-time orchestrator at
    the hierarchy column's uniform composition: 2 samples/node, one virtual
    batch per epoch spanning the whole dataset."""
    from repro.configs.paper_models import DATRET
    from repro.core.hierarchy import HierarchicalOrchestrator
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.plan import PlanSpec
    from repro.core.transport import NetworkModel, Transport
    from repro.models.small import SmallModel
    from repro.optim import sgd

    cfg = DATRET
    model = SmallModel(cfg)
    k = HIER_SAMPLES_PER_NODE
    r = np.random.default_rng(0)
    nodes = [TLNode(i, model,
                    r.normal(size=(k,) + cfg.in_shape).astype(np.float32),
                    r.integers(0, cfg.n_classes, k), jit_visits=True)
             for i in range(n_nodes)]
    tr = Transport(network=NetworkModel(bandwidth_bytes_per_s=HIER_BW,
                                        rtt_s=HIER_RTT))
    kw = dict(plan=PlanSpec(seed=0, batch_size=k * n_nodes),
              compute_time_fn=lambda m: SIM_COMPUTE_S_PER_SAMPLE * m,
              bp_time_fn=lambda m: SIM_BP_S_PER_SAMPLE * m, fused=True)
    if n_subtrees is None:
        orch = TLOrchestrator(model, nodes, sgd(0.05), tr, **kw)
    else:
        orch = HierarchicalOrchestrator(model, nodes, sgd(0.05), tr,
                                        n_subtrees=n_subtrees, **kw)
    orch.initialize(jax.random.PRNGKey(0))
    return orch


def _hier_spec(n_nodes: int, model_bytes: int):
    """The WorkloadSpec matching ``_build_hier_orchestrator`` byte for byte
    and tick for tick (SIM_* seconds re-expressed as FLOPs / FLOP rates)."""
    from repro.configs.paper_models import DATRET
    from repro.core.runtime_model import WorkloadSpec
    client = 1e12
    return WorkloadSpec(
        n_nodes=n_nodes, samples_per_node=HIER_SAMPLES_PER_NODE,
        batch_size=HIER_SAMPLES_PER_NODE * n_nodes,
        model_bytes=model_bytes,
        first_layer_bytes_per_sample=DATRET.hidden[0] * 4,
        logits_bytes_per_sample=DATRET.n_classes * 4,
        first_layer_param_bytes=(DATRET.in_shape[0] + 1)
        * DATRET.hidden[0] * 4,
        flops_per_sample_fwd=SIM_COMPUTE_S_PER_SAMPLE / 2 * client,
        flops_per_sample_bwd=SIM_COMPUTE_S_PER_SAMPLE / 2 * client,
        client_flops_per_s=client,
        server_flops_per_s=client * SIM_COMPUTE_S_PER_SAMPLE
        / SIM_BP_S_PER_SAMPLE,
        bandwidth_bytes_per_s=HIER_BW, rtt_s=HIER_RTT)


def _hierarchy_clock(node_counts=HIER_NODE_COUNTS) -> dict:
    """Clock vs node count, flat vs two-tier, measured (transport clock of a
    real simulated epoch) and predicted (eq. 19 two-tier branch).  The flat
    clock grows with the serial ΣT_comp,client + full-batch BP; the
    hierarchy divides both across subtree lanes and pays a serialized
    per-subtree merge — the crossover is the column's point."""
    from repro.core.runtime_model import runtime_tl
    from repro.core.transport import payload_bytes
    col = {}
    for n in node_counts:
        s = HIER_SUBTREES[n]
        flat = _build_hier_orchestrator(n, None)
        flat.train_epoch()
        jax.block_until_ready(flat.params)
        flat_clock = flat.transport.clock_s
        hier = _build_hier_orchestrator(n, s)
        hier.train_epoch()
        jax.block_until_ready(hier.params)
        hier_clock = hier.transport.clock_s
        spec = _hier_spec(n, payload_bytes(flat.params))
        pred_flat = runtime_tl(spec, hierarchy=1)
        pred_hier = runtime_tl(spec, hierarchy=s)
        col[str(n)] = {
            "n_subtrees": s,
            "flat_clock_s": round(flat_clock, 6),
            "two_tier_clock_s": round(hier_clock, 6),
            "speedup": round(flat_clock / hier_clock, 3),
            "predicted_flat_clock_s": round(pred_flat, 6),
            "predicted_two_tier_clock_s": round(pred_hier, 6),
            "predicted_err_flat": round(abs(pred_flat - flat_clock), 9),
            "predicted_err_two_tier": round(abs(pred_hier - hier_clock), 9),
        }
        print(f"bench_tl_step/hierarchy_nodes={n},"
              f"{hier_clock * 1e6:.0f},subtrees={s},"
              f"flat={flat_clock:.4f}s,two_tier={hier_clock:.4f}s,"
              f"speedup={flat_clock / hier_clock:.2f}x,"
              f"pred_err={abs(pred_hier - hier_clock):.2e}s")
    return col


def hierarchy_main(smoke: bool = False) -> dict:
    """Standalone hierarchy column (``benchmarks/run.py --only
    hierarchy_smoke`` runs the 64-node point as the CI smoke)."""
    counts = (64,) if smoke else HIER_NODE_COUNTS
    return {"model": "datret-mlp",
            "samples_per_node": HIER_SAMPLES_PER_NODE,
            "bandwidth_bytes_per_s": HIER_BW, "rtt_s": HIER_RTT,
            "backend": jax.default_backend(),
            "nodes": _hierarchy_clock(counts)}


def _simulated_clock(n_nodes: int, *, pipelined: bool) -> float:
    """Transport clock after one simulated-time epoch (fused path)."""
    orch = _build_orchestrator(n_nodes, fused=True, pipelined=pipelined,
                               simulate_time=True)
    orch.train_epoch()
    jax.block_until_ready(orch.params)
    return orch.transport.clock_s


_PRODUCTION_SCRIPT = textwrap.dedent("""
    import json, os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
    from repro.analysis.hlo_flops import analyze
    from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS)
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.core.tl_step import make_train_step, train_shardings
    from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                     synthetic_corpus)
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import adamw

    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    mesh = make_host_mesh()                       # (4, 2) over 8 devices

    # ---- production-shape dryrun: the engine's pjit step, timed ---------
    B, S = 16, 64
    shape = InputShape("dryrun", S, B, "train")
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(3e-4, clip_norm=1.0)
    st = opt.init(params)
    step = make_train_step(model, cfg, opt)
    r = np.random.default_rng(0)
    batch = {"tokens": r.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    batch["targets"] = np.roll(batch["tokens"], -1, 1)
    t0 = time.perf_counter()
    with mesh:
        in_sh, out_sh = train_shardings(params, st, cfg, mesh, shape)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        compiled = jitted.lower(params, st, batch).compile()
    t_compile = time.perf_counter() - t0
    costs = analyze(compiled.as_text())
    roofline_s = max(costs.flops / PEAK_FLOPS, costs.hbm_bytes / HBM_BW,
                     costs.coll_total / ICI_BW)
    p, s = params, st
    times = []
    for _ in range(6):
        t = time.perf_counter()
        p, s, loss = jitted(p, s, batch)
        jax.block_until_ready((p, loss))
        times.append(time.perf_counter() - t)
    dryrun = {"arch": cfg.name, "mesh_shape": list(mesh.devices.shape),
              "global_batch": B, "seq": S,
              "t_compile_s": round(t_compile, 3),
              "step_time_s_cpu": round(float(np.median(times[1:])), 4),
              "roofline_step_s_v5e": float(f"{roofline_s:.3e}"),
              "flops_per_chip": float(f"{costs.flops:.3e}"),
              "coll_bytes_per_chip": int(costs.coll_total)}

    # ---- engine wall-clock: serial vs pipelined at 2/4/8 nodes ----------
    # The loader carries a simulated IO-bound ingest latency per batch
    # (INGEST_S of sleep — disk/tokenizer wait, not CPU), mirroring how the
    # simulator columns use a simulated WAN clock: on a CPU backend the
    # "device" shares cores with the host, so pure-CPU host work cannot
    # demonstrate overlap.  Serial loading pays ingest on the critical path
    # every step; the 2-deep prefetch queue hides it behind device compute.
    # The step is kept small (1 layer, d=128) so ingest is a visible
    # fraction of the step, and this column runs on the single-device mesh:
    # the overlap is a property of the engine's producer thread, not of the
    # sharding, and the forced-8-device mesh's XLA thread pools oversubscribe
    # small CPU hosts so badly that compute jitter swamps the signal (the
    # sharded step's cost lives in the production_dryrun column above).
    from repro.launch.mesh import make_debug_mesh
    INGEST_S = 0.02
    import dataclasses
    ecfg = dataclasses.replace(cfg, name="engine-clock", n_layers=1,
                               d_model=128, n_heads=2, n_kv_heads=2,
                               d_ff=256, vocab_size=256)
    emodel = build_model(ecfg)
    EB, ES, STEPS = 8, 32, 32
    eng = Engine(emodel, ecfg, adamw(3e-4, clip_norm=1.0),
                 make_debug_mesh(1, 1),
                 InputShape("bench", ES, EB, "train"))
    eng.init(jax.random.PRNGKey(0))

    def loader(n_nodes):
        docs = synthetic_corpus(n_nodes * 64, ES, ecfg.vocab_size, seed=1)
        for hb in VirtualBatchLoader(shard_corpus(docs, n_nodes), EB, seed=0):
            time.sleep(INGEST_S)                  # simulated IO-bound ingest
            yield hb

    eng.run(loader(2), steps=8)                   # compile + warmup
    clocks = {}
    for n in (2, 4, 8):
        serial, piped = [], []
        for _ in range(3):                        # min-of-3: dodge host noise
            eng.pipeline = False
            serial.append(eng.run(loader(n), steps=STEPS).wall_s)
            eng.pipeline = True
            piped.append(eng.run(loader(n), steps=STEPS).wall_s)
        serial, piped = min(serial), min(piped)
        clocks[str(n)] = {
            "ingest_s_per_batch": INGEST_S,
            "serial_wall_s": round(serial, 4),
            "pipelined_wall_s": round(piped, 4),
            "overlap_gain": round(serial / piped, 3)}

    print("RESULT", json.dumps({"production_dryrun": dryrun,
                                "engine_clock": clocks}))
""")

# The elastic-recovery measurement runs in its OWN subprocess, with the
# persistent compilation cache left OFF: the recovery path re-jits the same
# step across shrinking meshes, and jax 0.4.37's CPU persistent-cache
# serialization corrupts the heap on that pattern (glibc "corrupted
# double-linked list" abort inside the first recovery re-jit when
# jax_compilation_cache_dir is set; clean without it).  Keeping it separate
# also means a crash here degrades to an `elastic_error` column instead of
# taking the dryrun/engine-clock columns down with it.
_ELASTIC_SCRIPT = textwrap.dedent("""
    import dataclasses, json, os, tempfile, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import (VirtualBatchLoader, shard_corpus,
                                     synthetic_corpus)
    from repro.launch.elastic import KILL, DeviceFaultSpec, Drill
    from repro.launch.engine import Engine
    from repro.models import build_model
    from repro.optim import adamw

    # ---- elastic recovery: detect -> reshrink -> restore -> replay ------
    # A scripted chip kill at step 3 on (1,2)/(2,2)/(4,2) meshes over the
    # first 2/4/8 forced host devices; the engine's RecoveryReport is the
    # measurement.  ckpt_every=2 puts a checkpoint at step 2 (rollback
    # depth 1); ckpt_every=4 leaves only the step-0 anchor (depth 3) — the
    # depth axis of runtime_model.recovery_cost.
    cfg = get_config("deepseek-7b", reduced=True)
    ecfg = dataclasses.replace(cfg, name="engine-clock", n_layers=1,
                               d_model=128, n_heads=2, n_kv_heads=2,
                               d_ff=256, vocab_size=256)
    emodel = build_model(ecfg)
    EB, ES = 8, 32
    devs = jax.devices()
    docs_e = synthetic_corpus(2 * 64, ES, ecfg.vocab_size, seed=1)
    vbl = VirtualBatchLoader(shard_corpus(docs_e, 2), EB, seed=0)
    elastic = {}
    for n in (2, 4, 8):
        mesh_n = jax.sharding.Mesh(
            np.array(devs[:n]).reshape(n // 2, 2), ("data", "model"))
        per_cadence = {}
        for ckpt_every in (2, 4):
            eng_e = Engine(
                emodel, ecfg, adamw(3e-4, clip_norm=1.0), mesh_n,
                InputShape("bench", ES, EB, "train"),
                ckpt_dir=tempfile.mkdtemp(), ckpt_every=ckpt_every,
                elastic=True, watchdog_s=300.0,
                device_faults=DeviceFaultSpec(
                    drills=(Drill(KILL, 3, devs[0].id),)))
            eng_e.init(jax.random.PRNGKey(0))
            res = eng_e.run(vbl, steps=5)
            rec = res.recovery[0].as_dict()
            rec["n_devices"] = n
            per_cadence[f"ckpt_every_{ckpt_every}"] = rec
        elastic[str(n)] = per_cadence

    print("RESULT", json.dumps({"elastic_recovery": elastic}))
""")


def _run_result_script(script: str, error_key: str, timeout_s: int) -> dict:
    """Run one measurement subprocess; degrade to an ``{error_key: ...}``
    column on timeout/crash so the columns already computed this run still
    reach the trajectory."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src"))
    try:
        proc = subprocess.run([sys.executable, "-c", script],
                              env=env, capture_output=True, text=True,
                              timeout=timeout_s)
    except subprocess.TimeoutExpired:
        return {error_key: f"subprocess timed out ({timeout_s}s)"}
    if proc.returncode != 0:
        return {error_key: proc.stderr[-2000:]}
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line.split("RESULT ")[1])


def _production_columns() -> dict:
    """Run the production-path measurements in subprocesses (the forced
    8-device count must never leak into this process's jax; the elastic
    drill additionally needs the persistent compilation cache off — see
    ``_ELASTIC_SCRIPT``)."""
    out = _run_result_script(_PRODUCTION_SCRIPT, "production_error", 1500)
    out.update(_run_result_script(_ELASTIC_SCRIPT, "elastic_error", 900))
    if "production_error" in out:
        return out
    d = out["production_dryrun"]
    print(f"bench_tl_step/production_dryrun,"
          f"{d['step_time_s_cpu'] * 1e6:.0f},"
          f"roofline_v5e={d['roofline_step_s_v5e']:.2e}s")
    for n, c in out["engine_clock"].items():
        print(f"bench_tl_step/engine_nodes={n},"
              f"{c['pipelined_wall_s'] * 1e6:.0f},"
              f"overlap_gain={c['overlap_gain']}x")
    for n, cad in out.get("elastic_recovery", {}).items():
        for name, rec in cad.items():
            print(f"bench_tl_step/elastic_devices={n}/{name},"
                  f"{rec['total_s'] * 1e6:.0f},"
                  f"depth={rec['rollback_depth']},"
                  f"rejit={rec['rejit_s']:.2f}s")
    return out


def _load_runs(out_path: str) -> list:
    """Existing trajectory; a legacy single-run dict is migrated in place
    as the trajectory's first entry (for the root artifact that's PR 1's
    fused-vs-eager baseline, whose rev is known)."""
    if not os.path.exists(out_path):
        return []
    with open(out_path) as f:
        data = json.load(f)
    if isinstance(data, dict):                             # legacy format
        legacy_rev = ("822cfe8" if os.path.abspath(out_path) == OUT_PATH
                      else "unknown")
        data.setdefault("git_rev", legacy_rev)
        data.setdefault("legacy", True)     # never displaced by re-runs
        return [data]
    return data


def run(node_counts=(2, 4, 8), epochs: int = 3,
        out_path: Optional[str] = OUT_PATH,
        production: bool = True, hierarchy: bool = True) -> dict:
    """One benchmark entry.  ``out_path=None`` skips the trajectory write
    (smoke mode: ``benchmarks/run.py`` wraps the returned entry in its
    standard ``BENCH_<name>.json`` artifact instead)."""
    results = {}
    for n in node_counts:
        eager = _measure(_build_orchestrator(n, fused=False), epochs)
        fused = _measure(_build_orchestrator(n, fused=True), epochs)
        pallas = _measure(_build_orchestrator(n, fused=True,
                                              reassembly="pallas"), epochs)
        piped = _measure(_build_orchestrator(n, fused=True, pipelined=True),
                         epochs)
        clock_serial = _simulated_clock(n, pipelined=False)
        clock_piped = _simulated_clock(n, pipelined=True)
        wire = _wire_compression(n, epochs)
        results[str(n)] = {
            "eager_steps_per_s": round(eager, 2),
            "fused_steps_per_s": round(fused, 2),
            "pipelined_steps_per_s": round(piped, 2),
            "speedup": round(fused / eager, 2),
            "reassembly": {
                "xla_steps_per_s": round(fused, 2),
                "pallas_steps_per_s": round(pallas, 2),
            },
            "serial_clock_s": round(clock_serial, 4),
            "pipelined_clock_s": round(clock_piped, 4),
            "clock_speedup": round(clock_serial / clock_piped, 3),
            "wire_compression": wire,
        }
        print(f"bench_tl_step/nodes={n},"
              f"{1e6 / fused:.0f},speedup={fused / eager:.2f}x,"
              f"reassembly_pallas={pallas:.2f}steps/s,"
              f"clock={clock_serial:.3f}s->{clock_piped:.3f}s,"
              f"wire_int8={wire['int8']['bytes_ratio']}x,"
              f"wire_fp8_ef={wire['fp8_ef']['bytes_ratio']}x")
    entry = {
        "git_rev": _git_rev(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "benchmark": "tl_step",
        "model": "datret-mlp",
        "batch_size": BATCH_SIZE,
        "total_samples": TOTAL_SAMPLES,
        "epochs_measured": epochs,
        "backend": jax.default_backend(),
        "nodes": results,
    }
    if hierarchy:
        # clock vs node count far beyond the flat sweep: flat serial vs
        # two-tier, measured and eq.-19-predicted, at 64/256/1024 nodes
        entry["hierarchy"] = _hierarchy_clock()
    if production:
        entry.update(_production_columns())
    if out_path is not None:
        # one entry per git rev: a re-run at the same checkout replaces its
        # own earlier entry instead of duplicating it (the trajectory is
        # per-PR).  Migrated legacy baselines are immune — a dirty tree
        # sitting on the baseline's rev must not displace the baseline it
        # is compared against.
        runs = [r for r in _load_runs(out_path)
                if r.get("legacy") or r.get("git_rev") != entry["git_rev"]]
        runs.append(entry)
        with open(out_path, "w") as f:
            json.dump(runs, f, indent=1)
        print(f"bench_tl_step/artifact,{out_path} ({len(runs)} runs)")
    return entry


def main(smoke: bool = False) -> dict:
    if smoke:
        # fast per-PR regression signal: 2 nodes, one measured epoch, same
        # entry shape, no production subprocess and no hierarchy sweep (the
        # hierarchy smoke is its own run.py entry, ``hierarchy_smoke``).
        # The smoke artifact is written by benchmarks/run.py's standard
        # wrapper (BENCH_tl_step_smoke.json), not by this module — the
        # trajectory file stays full-sweep-only.
        return run(node_counts=(2,), epochs=1, out_path=None,
                   production=False, hierarchy=False)
    return run()


if __name__ == "__main__":
    import sys
    art = main(smoke="--smoke" in sys.argv)
    worst = min(v["speedup"] for v in art["nodes"].values())
    print(f"bench_tl_step/min_speedup,{worst}")
