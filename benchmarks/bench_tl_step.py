"""TL step-time benchmark: eager reference vs fused jitted hot path.

Measures steps/sec of the protocol simulator's full TL round (model
redistribution + node visits + centralized BP + update) at 2/4/8 simulated
nodes, for

* ``eager`` — the seed's op-by-op path: unjitted node visits, per-node
  ``.at[].set`` scatters, an un-jitted tail vjp per virtual batch, host
  syncs inside every visit;
* ``fused`` — jitted node visits with device-resident stats, one batched
  scatter reassembly, and a single compiled (donated) vjp+update step.

Writes ``BENCH_tl_step.json`` at the repo root — the seed of the repo's
step-time perf trajectory; run via ``benchmarks/run.py`` (smoke) or
directly: ``PYTHONPATH=src python benchmarks/bench_tl_step.py``.
"""
import json
import os
import time

import jax
import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_tl_step.json")

TOTAL_SAMPLES = 512
BATCH_SIZE = 64


def _build_orchestrator(n_nodes: int, *, fused: bool):
    from repro.configs.paper_models import DATRET
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.transport import Transport
    from repro.models.small import SmallModel
    from repro.optim import sgd

    cfg = DATRET
    model = SmallModel(cfg)
    per_node = TOTAL_SAMPLES // n_nodes
    r = np.random.default_rng(0)
    nodes = [TLNode(i, model,
                    r.normal(size=(per_node,) + cfg.in_shape).astype(np.float32),
                    r.integers(0, cfg.n_classes, per_node),
                    jit_visits=fused)
             for i in range(n_nodes)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=BATCH_SIZE, seed=0,
                          fused=fused, donate=fused)
    orch.initialize(jax.random.PRNGKey(0))
    return orch


def _measure(orch, epochs: int) -> float:
    """Steps/sec over `epochs` epochs (one warmup epoch first)."""
    orch.train_epoch()                                     # warmup + compile
    jax.block_until_ready(orch.params)
    steps = 0
    t0 = time.perf_counter()
    for _ in range(epochs):
        steps += len(orch.train_epoch())
    jax.block_until_ready(orch.params)
    return steps / (time.perf_counter() - t0)


def run(node_counts=(2, 4, 8), epochs: int = 3, out_path: str = OUT_PATH) -> dict:
    results = {}
    for n in node_counts:
        eager = _measure(_build_orchestrator(n, fused=False), epochs)
        fused = _measure(_build_orchestrator(n, fused=True), epochs)
        results[str(n)] = {
            "eager_steps_per_s": round(eager, 2),
            "fused_steps_per_s": round(fused, 2),
            "speedup": round(fused / eager, 2),
        }
        print(f"bench_tl_step/nodes={n},"
              f"{1e6 / fused:.0f},speedup={fused / eager:.2f}x")
    art = {
        "benchmark": "tl_step",
        "model": "datret-mlp",
        "batch_size": BATCH_SIZE,
        "total_samples": TOTAL_SAMPLES,
        "epochs_measured": epochs,
        "backend": jax.default_backend(),
        "nodes": results,
    }
    with open(out_path, "w") as f:
        json.dump(art, f, indent=1)
    print(f"bench_tl_step/artifact,{out_path}")
    return art


def main(smoke: bool = False) -> dict:
    if smoke:
        # fast per-PR regression signal: 2 nodes, one measured epoch, same
        # JSON shape — written beside (never over) the full-sweep artifact
        return run(node_counts=(2,), epochs=1,
                   out_path=os.path.join(REPO_ROOT,
                                         "BENCH_tl_step_smoke.json"))
    return run()


if __name__ == "__main__":
    import sys
    art = main(smoke="--smoke" in sys.argv)
    worst = min(v["speedup"] for v in art["nodes"].values())
    print(f"bench_tl_step/min_speedup,{worst}")
