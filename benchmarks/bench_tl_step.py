"""TL step-time benchmark: eager reference vs fused vs pipelined hot path.

Measures steps/sec of the protocol simulator's full TL round (model
redistribution + node visits + centralized BP + update) at 2/4/8 simulated
nodes, for

* ``eager`` — the seed's op-by-op path: unjitted node visits, per-node
  ``.at[].set`` scatters, an un-jitted tail vjp per virtual batch, host
  syncs inside every visit;
* ``fused`` — jitted node visits with device-resident stats, one batched
  scatter reassembly, and a single compiled (donated) vjp+update step;
* ``pipelined`` — the fused path driven by the double-buffered epoch engine
  (``repro.core.pipeline``): batch k+1's visits produced while batch k's
  centralized BP consumes.

Pipelining is a *clock* optimization in the protocol simulator, so besides
wall-clock steps/sec the benchmark runs a simulated-time epoch (nonzero node
compute + centralized-BP cost on a WAN network model) serial vs pipelined
and records ``Transport.clock_s`` for each — the measurable counterpart of
runtime_model's eq. 19 pipelined form.  The clock columns are the
headline signal: the steps/sec columns share one process's executable
caches (later configurations run warmer), so cross-column wall-clock
ratios carry cache noise the simulated clock does not.

``BENCH_tl_step.json`` at the repo root is the repo's step-time perf
*trajectory*: a list of runs keyed by git rev, appended to (never
overwritten) on each invocation; run via ``benchmarks/run.py`` (smoke) or
directly: ``PYTHONPATH=src python benchmarks/bench_tl_step.py``.
"""
import json
import os
import subprocess
import time

import jax
import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_tl_step.json")

TOTAL_SAMPLES = 512
BATCH_SIZE = 64

# simulated cost model for the clock columns: node FP+local-BP compute per
# sample, and orchestrator centralized-BP per virtual-batch sample
SIM_COMPUTE_S_PER_SAMPLE = 1e-4
SIM_BP_S_PER_SAMPLE = 5e-4


def _git_rev() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              cwd=REPO_ROOT, capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def _build_orchestrator(n_nodes: int, *, fused: bool, pipelined: bool = False,
                        simulate_time: bool = False):
    from repro.configs.paper_models import DATRET
    from repro.core.node import TLNode
    from repro.core.orchestrator import TLOrchestrator
    from repro.core.transport import Transport
    from repro.models.small import SmallModel
    from repro.optim import sgd

    cfg = DATRET
    model = SmallModel(cfg)
    per_node = TOTAL_SAMPLES // n_nodes
    r = np.random.default_rng(0)
    nodes = [TLNode(i, model,
                    r.normal(size=(per_node,) + cfg.in_shape).astype(np.float32),
                    r.integers(0, cfg.n_classes, per_node),
                    jit_visits=fused)
             for i in range(n_nodes)]
    time_kw = {}
    if simulate_time:
        time_kw = dict(
            compute_time_fn=lambda k: SIM_COMPUTE_S_PER_SAMPLE * k,
            bp_time_fn=lambda n: SIM_BP_S_PER_SAMPLE * n)
    orch = TLOrchestrator(model, nodes, sgd(0.05), Transport(),
                          batch_size=BATCH_SIZE, seed=0,
                          fused=fused, donate=fused, pipelined=pipelined,
                          **time_kw)
    orch.initialize(jax.random.PRNGKey(0))
    return orch


# Each epoch reshuffles the traversal plan, so segment lengths — and with
# them the bucket-padded visit shapes and eager pad/slice executables —
# keep producing NEW compilations for the first ~3 epochs before the shape
# space is covered.  A single warmup epoch (the original methodology) puts
# epoch 1's ~84 compiles inside the measured window and understates
# steps/sec by ~10x for whichever configuration runs first in the process.
WARMUP_EPOCHS = 4


def _measure(orch, epochs: int) -> float:
    """Steps/sec over `epochs` epochs after a shape-space-covering warmup."""
    for _ in range(WARMUP_EPOCHS):                         # warmup + compile
        orch.train_epoch()
    jax.block_until_ready(orch.params)
    steps = 0
    t0 = time.perf_counter()
    for _ in range(epochs):
        steps += len(orch.train_epoch())
    jax.block_until_ready(orch.params)
    return steps / (time.perf_counter() - t0)


def _simulated_clock(n_nodes: int, *, pipelined: bool) -> float:
    """Transport clock after one simulated-time epoch (fused path)."""
    orch = _build_orchestrator(n_nodes, fused=True, pipelined=pipelined,
                               simulate_time=True)
    orch.train_epoch()
    jax.block_until_ready(orch.params)
    return orch.transport.clock_s


def _load_runs(out_path: str) -> list:
    """Existing trajectory; a legacy single-run dict is migrated in place
    as the trajectory's first entry (for the root artifact that's PR 1's
    fused-vs-eager baseline, whose rev is known)."""
    if not os.path.exists(out_path):
        return []
    with open(out_path) as f:
        data = json.load(f)
    if isinstance(data, dict):                             # legacy format
        legacy_rev = ("822cfe8" if os.path.abspath(out_path) == OUT_PATH
                      else "unknown")
        data.setdefault("git_rev", legacy_rev)
        data.setdefault("legacy", True)     # never displaced by re-runs
        return [data]
    return data


def run(node_counts=(2, 4, 8), epochs: int = 3, out_path: str = OUT_PATH) -> dict:
    results = {}
    for n in node_counts:
        eager = _measure(_build_orchestrator(n, fused=False), epochs)
        fused = _measure(_build_orchestrator(n, fused=True), epochs)
        piped = _measure(_build_orchestrator(n, fused=True, pipelined=True),
                         epochs)
        clock_serial = _simulated_clock(n, pipelined=False)
        clock_piped = _simulated_clock(n, pipelined=True)
        results[str(n)] = {
            "eager_steps_per_s": round(eager, 2),
            "fused_steps_per_s": round(fused, 2),
            "pipelined_steps_per_s": round(piped, 2),
            "speedup": round(fused / eager, 2),
            "serial_clock_s": round(clock_serial, 4),
            "pipelined_clock_s": round(clock_piped, 4),
            "clock_speedup": round(clock_serial / clock_piped, 3),
        }
        print(f"bench_tl_step/nodes={n},"
              f"{1e6 / fused:.0f},speedup={fused / eager:.2f}x,"
              f"clock={clock_serial:.3f}s->{clock_piped:.3f}s")
    entry = {
        "git_rev": _git_rev(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "benchmark": "tl_step",
        "model": "datret-mlp",
        "batch_size": BATCH_SIZE,
        "total_samples": TOTAL_SAMPLES,
        "epochs_measured": epochs,
        "backend": jax.default_backend(),
        "nodes": results,
    }
    # one entry per git rev: a re-run at the same checkout replaces its own
    # earlier entry instead of duplicating it (the trajectory is per-PR).
    # Migrated legacy baselines are immune — a dirty tree sitting on the
    # baseline's rev must not displace the baseline it is compared against.
    runs = [r for r in _load_runs(out_path)
            if r.get("legacy") or r.get("git_rev") != entry["git_rev"]]
    runs.append(entry)
    with open(out_path, "w") as f:
        json.dump(runs, f, indent=1)
    print(f"bench_tl_step/artifact,{out_path} ({len(runs)} runs)")
    return entry


def main(smoke: bool = False) -> dict:
    if smoke:
        # fast per-PR regression signal: 2 nodes, one measured epoch, same
        # JSON shape — written beside (never over) the full-sweep artifact
        return run(node_counts=(2,), epochs=1,
                   out_path=os.path.join(REPO_ROOT,
                                         "BENCH_tl_step_smoke.json"))
    return run()


if __name__ == "__main__":
    import sys
    art = main(smoke="--smoke" in sys.argv)
    worst = min(v["speedup"] for v in art["nodes"].values())
    print(f"bench_tl_step/min_speedup,{worst}")
