# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness:

  table1_quality  — paper Table 1: quality of CL/TL/FL/SL/SL+/SFL across
                    four dataset families
  table2_runtime  — paper Table 2: per-round runtime + bytes (analytic
                    eqs. 15-19 + transport-simulated)
  fig3_scaling    — paper Fig. 3: runtime vs node count
  roofline_report — the roofline table from the dry-run artifacts
"""
import sys
import time


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    from benchmarks import fig3_scaling, roofline_report, table1_quality, \
        table2_runtime
    failures = []
    for name, mod in [("table2_runtime", table2_runtime),
                      ("fig3_scaling", fig3_scaling),
                      ("roofline_report", roofline_report),
                      ("table1_quality", table1_quality)]:
        t = time.time()
        try:
            mod.main()
            print(f"{name}/total,{(time.time()-t)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name}/total,{(time.time()-t)*1e6:.0f},FAILED:{e}")
    print(f"all/total,{(time.time()-t0)*1e6:.0f},"
          f"{'ok' if not failures else failures}")
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
