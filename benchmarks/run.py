# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV and writes a machine-readable ``BENCH_<name>.json`` per benchmark at
# the repo root, so per-PR perf regressions are diffable artifacts, not just
# stdout.
"""Benchmark harness:

  table2_runtime  — paper Table 2: per-round runtime + bytes (analytic
                    eqs. 15-19 + transport-simulated)
  fig3_scaling    — paper Fig. 3: runtime vs node count
  roofline_report — the roofline table from the dry-run artifacts
  bench_tl_step   — eager vs fused TL step-time (smoke: 2 nodes); the
                    full sweep is ``python benchmarks/bench_tl_step.py``
  hierarchy_smoke — two-tier (hierarchical) vs flat simulated clock at 64
                    nodes; the 64/256/1024 sweep rides the full tl_step run
  table1_quality  — paper Table 1: quality of CL/TL/FL/SL/SL+/SFL across
                    four dataset families
  serve           — open-loop Poisson serving benchmark: continuous batching
                    + paged KV cache, tokens/s and p50/p99 per-token latency
                    vs offered load (``serve_smoke`` is the CI grid)

``--only name[,name...]`` runs a subset (CI's smoke-benchmark step runs
``--only tl_step_smoke`` and schema-gates the artifact it emits).
"""
import argparse
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write_artifact(name: str, payload: dict) -> str:
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run "
                         "(default: all)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    t0 = time.time()
    from benchmarks import (bench_serve, bench_tl_step, fig3_scaling,
                            roofline_report, table1_quality, table2_runtime)
    failures = []
    entries = [
        ("table2_runtime", table2_runtime.main),
        ("fig3_scaling", fig3_scaling.main),
        ("roofline_report", roofline_report.main),
        # smoke entry flows through the standard wrapper artifact
        # (BENCH_tl_step_smoke.json) like every other benchmark; only the
        # full sweep appends to the BENCH_tl_step.json trajectory
        ("tl_step_smoke", lambda: bench_tl_step.main(smoke=True)),
        # two-tier hierarchy clock at 64 simulated nodes (the full
        # 64/256/1024 sweep rides the bench_tl_step full run)
        ("hierarchy_smoke", lambda: bench_tl_step.hierarchy_main(smoke=True)),
        ("table1_quality", table1_quality.main),
        ("serve", bench_serve.main),
        ("serve_smoke", lambda: bench_serve.main(smoke=True)),
    ]
    if args.only:
        wanted = {w.strip() for w in args.only.split(",")}
        unknown = wanted - {n for n, _ in entries}
        if unknown:
            raise SystemExit(f"unknown benchmark(s): {sorted(unknown)}")
        entries = [(n, f) for n, f in entries if n in wanted]
    for name, fn in entries:
        t = time.time()
        try:
            result = fn()
            dt = time.time() - t
            art = {"benchmark": name, "status": "ok",
                   "seconds": round(dt, 3)}
            if isinstance(result, dict):
                art["result"] = result
            _write_artifact(name, art)
            print(f"{name}/total,{dt * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            dt = time.time() - t
            failures.append((name, e))
            _write_artifact(name, {"benchmark": name, "status": "error",
                                   "seconds": round(dt, 3),
                                   "error": f"{type(e).__name__}: {e}"})
            print(f"{name}/total,{dt * 1e6:.0f},FAILED:{e}")
    print(f"all/total,{(time.time() - t0) * 1e6:.0f},"
          f"{'ok' if not failures else failures}")
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
