"""Paper Table 2 analogue: per-round runtime + communication bytes of each
method at 20 nodes, from (a) the analytic model (eqs. 15–19) and (b) the
transport simulator's byte/clock accounting on a real protocol run.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs.paper_models import DATRET
from repro.core import baselines as B
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.plan import PlanSpec
from repro.core.runtime_model import (WorkloadSpec, runtime_fl, runtime_sfl,
                                      runtime_sl, runtime_slp, runtime_tl)
from repro.core.transport import NetworkModel, Transport, WirePolicy
from repro.data.datasets import shard_iid, tabular
from repro.models.small import SmallModel
from repro.optim import sgd


def analytic_rows(n_nodes=20):
    # ResNet-18/MNIST constants: X^(1) is the post-pool 64×14×14 stem output
    # (50 KB/sample f32).  NOTE (sensitivity, EXPERIMENTS.md): the paper's
    # "TL cheapest" ordering requires |X^(1)|·samples ≲ |θ| per round — with
    # pre-pool 28×28 activations (4× bigger) TL's wire cost exceeds FedAvg's.
    spec = WorkloadSpec(
        n_nodes=n_nodes, samples_per_node=500, batch_size=50,
        model_bytes=45e6,                      # ~ResNet-18 f32
        first_layer_bytes_per_sample=64 * 14 * 14 * 4,
        logits_bytes_per_sample=40,
        first_layer_param_bytes=64 * 9 * 4,
        flops_per_sample_fwd=1.8e9, flops_per_sample_bwd=3.6e9,
        client_flops_per_s=5e12, server_flops_per_s=1e14)
    return {
        "FL": runtime_fl(spec), "SL": runtime_sl(spec),
        "SL+": runtime_slp(spec), "SFL": runtime_sfl(spec),
        "TL": runtime_tl(spec, cache_model=True),
        "TL+compress": runtime_tl(spec, cache_model=True, compressed=True),
    }


def simulated_rows(n_nodes=8, compress=False):
    """Run one real protocol round per method through the byte-accounting
    transport (reduced sizes: CPU)."""
    ds = tabular(n_nodes * 60, 32, 4, seed=0)
    shards = shard_iid(ds, n_nodes, seed=0)
    sdata = [B.ShardData(jax.numpy.asarray(s.x), jax.numpy.asarray(s.y))
             for s in shards]
    model = SmallModel(dataclasses.replace(DATRET, n_classes=4))
    key = jax.random.PRNGKey(0)
    net = NetworkModel(bandwidth_bytes_per_s=1e9 / 8, rtt_s=0.02)
    out = {}

    tr = Transport(network=net)
    B.train_fl(model, sdata, sgd(0.05), key=key, rounds=1, local_epochs=1,
               batch_size=30, transport=tr)
    out["FL"] = (tr.clock_s, tr.total_bytes)

    tr = Transport(network=net)
    B.train_sl(model, sdata, sgd(0.05), key=key, rounds=1, batch_size=30,
               transport=tr)
    out["SL"] = (tr.clock_s, tr.total_bytes)

    tr = Transport(network=net)
    B.train_sl(model, sdata, sgd(0.05), key=key, rounds=1, batch_size=30,
               transport=tr, no_label_sharing=True)
    out["SL+"] = (tr.clock_s, tr.total_bytes)

    tr = Transport(network=net)
    B.train_sfl(model, sdata, sgd(0.05), key=key, rounds=1, batch_size=30,
                transport=tr)
    out["SFL"] = (tr.clock_s, tr.total_bytes)

    tr = Transport(network=net,
                   wire=WirePolicy.visits("int8") if compress else None)
    nodes = [TLNode(i, model, s.x, s.y) for i, s in enumerate(shards)]
    orch = TLOrchestrator(model, nodes, sgd(0.05), tr, batch_size=30,
                          plan=PlanSpec(seed=0), check_consistency=False,
                          cache_model_per_epoch=True)
    orch.initialize(key)
    orch.train_epoch()
    out["TL" + ("+compress" if compress else "")] = (tr.clock_s,
                                                     tr.total_bytes)
    return out


def main():
    t0 = time.time()
    ana = analytic_rows()
    for m, v in ana.items():
        print(f"table2/analytic_runtime_s/{m},{(time.time()-t0)*1e6:.0f},{v:.3f}")
    t0 = time.time()
    sim = simulated_rows()
    sim.update(simulated_rows(compress=True))
    for m, (clock, nbytes) in sim.items():
        print(f"table2/simulated_clock_s/{m},{(time.time()-t0)*1e6:.0f},{clock:.4f}")
        print(f"table2/simulated_bytes/{m},{(time.time()-t0)*1e6:.0f},{nbytes}")
    # the paper's ordering claims
    assert ana["TL"] < ana["FL"] and ana["TL"] < ana["SFL"] < ana["SL"] < ana["SL+"]
    return {"analytic": ana, "simulated": sim}


if __name__ == "__main__":
    main()
