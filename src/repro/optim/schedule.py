"""Learning-rate schedules (step -> lr)."""
import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr, total_steps, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr, warmup_steps, total_steps, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
