from repro.optim.optimizers import Optimizer, adafactor, adam, adamw, sgd
from repro.optim.schedule import constant, cosine_decay, warmup_cosine

__all__ = ["Optimizer", "sgd", "adam", "adamw", "adafactor",
           "constant", "cosine_decay", "warmup_cosine"]
