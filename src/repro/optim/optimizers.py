"""Pure-pytree optimizers: SGD(+momentum), Adam(W), Adafactor.

API: ``opt.init(params) -> state``; ``opt.update(params, grads, state) ->
(new_params, new_state)``.  All state is a pytree, so optimizer state shards
with the same PartitionSpecs as the parameters (plus a scalar step).

Adafactor (factored second moments) exists because Adam's fp32 state for a
671B-parameter model (~8 TB) cannot fit a 256-chip v5e pod; Adafactor's
row+col factors cut second-moment memory by ~d/2.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable                # (params, grads, state) -> (params, state)


def _schedule(lr):
    return lr if callable(lr) else (lambda step: lr)


def _clip_by_global_norm(grads, max_norm):
    if max_norm is None:
        return grads
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


# ---------------------------------------------------------------------- SGD

def sgd(lr, momentum: float = 0.0, clip_norm: Optional[float] = None):
    lr_fn = _schedule(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(params, grads, state):
        grads = _clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        eta = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            new = jax.tree.map(lambda p, m: p - eta * m, params, mu)
            return new, {"step": step, "mu": mu}
        new = jax.tree.map(lambda p, g: p - eta * g, params, grads)
        return new, {"step": step}

    return Optimizer(init, update)


# --------------------------------------------------------------------- Adam

def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, clip_norm: Optional[float] = None):
    lr_fn = _schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(params, grads, state):
        grads = _clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        eta = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * u).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw):
    return adam(lr, weight_decay=weight_decay, **kw)


# ---------------------------------------------------------------- Adafactor

def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0):
    """Factored second-moment optimizer (Shazeer & Stern, 2018), the
    memory-frugal choice for the ≥200B assigned architectures."""
    lr_fn = _schedule(lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf_state(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "slots": jax.tree.map(leaf_state, params,
                                      is_leaf=lambda x: hasattr(x, "shape"))}

    def update(params, grads, state):
        step = state["step"] + 1
        eta = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def upd(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))
                cfac = jax.lax.rsqrt(vc)
                u = g32 * rfac[..., None] * cfac[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - eta * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        slots_leaves = tdef.flatten_up_to(state["slots"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, slots_leaves)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_slots = jax.tree.unflatten(tdef, [o[1] for o in out])
        return new_p, {"step": step, "slots": new_slots}

    return Optimizer(init, update)
