from repro.checkpoint.ckpt import (gc_checkpoints, latest_step,
                                   load_checkpoint, save_checkpoint,
                                   verify_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "gc_checkpoints", "verify_checkpoint"]
