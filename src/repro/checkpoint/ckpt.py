"""Pytree checkpointing: npz payload + json treedef, atomic, step-indexed.

Layout:  <dir>/step_<N>/arrays.npz + meta.json
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names, leaves = [], []
    for path, leaf in leaves_with_paths:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves


def _to_savable(arr: np.ndarray):
    """npz can't store ml_dtypes (bfloat16 etc.); save a raw view + dtype."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,)), \
            arr.dtype.name
    try:
        np.dtype(arr.dtype.name)
        native = True
    except TypeError:
        native = False
    if not native or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                        "float8_e5m2"):
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,)), \
            arr.dtype.name
    return arr, arr.dtype.name


def _from_savable(arr: np.ndarray, dtype_name: str):
    if arr.dtype == np.uint8 and dtype_name not in ("uint8",):
        import ml_dtypes
        dt = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
        return arr.reshape(arr.shape[:-1] + (-1,)).ravel().view(dt).reshape(
            arr.shape[:-1])
    return arr


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    names, leaves = _flatten_with_names(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    try:
        savable = [_to_savable(l) for l in leaves]
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, (a, _) in enumerate(savable)})
        meta = {"step": step, "names": names,
                "dtypes": [d for _, d in savable],
                "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, tree_like, step: Optional[int] = None
                    ) -> Tuple[Any, dict]:
    """Restore into the structure of ``tree_like`` (names must match)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    names_now, _ = _flatten_with_names(tree_like)
    if names_now != meta["names"]:
        raise ValueError("checkpoint tree structure mismatch")
    leaves = [_from_savable(data[f"a{i}"], meta["dtypes"][i])
              for i in range(len(meta["names"]))]
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
