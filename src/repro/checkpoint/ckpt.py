"""Pytree checkpointing: npz payload + json treedef, atomic, step-indexed.

Layout:  <dir>/step_<N>/arrays.npz + meta.json

Integrity + durability (the elastic engine's rollback anchor):

* every array's SHA-256 goes into ``meta.json`` at save time and is
  re-verified on restore — a bit-flipped or truncated payload can never be
  silently trained on;
* the npz and meta files are fsync'd (and the directory entries flushed)
  *before* the atomic rename publishes the step, so a host crash between
  save and rename leaves either the previous step or a complete new one,
  never a half-written directory with a valid name;
* a corrupt or truncated ``step_N`` directory (missing ``arrays.npz``,
  checksum mismatch, unreadable meta) is *skipped with a warning* by
  :func:`latest_step` / :func:`load_checkpoint`'s latest-step resolution,
  which fall back to the newest **valid** step instead of crashing — a
  partially-destroyed checkpoint directory degrades the rollback depth, not
  the recovery itself;
* :func:`gc_checkpoints` bounds the directory's growth for long elastic
  runs (``--ckpt-keep``), never collecting protected steps (the one a live
  resume depends on).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import warnings
from typing import Any, Iterable, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names, leaves = [], []
    for path, leaf in leaves_with_paths:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves


def _to_savable(arr: np.ndarray):
    """npz can't store ml_dtypes (bfloat16 etc.); save a raw view + dtype."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,)), \
            arr.dtype.name
    try:
        np.dtype(arr.dtype.name)
        native = True
    except TypeError:
        native = False
    if not native or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                        "float8_e5m2"):
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,)), \
            arr.dtype.name
    return arr, arr.dtype.name


def _from_savable(arr: np.ndarray, dtype_name: str):
    if arr.dtype == np.uint8 and dtype_name not in ("uint8",):
        import ml_dtypes
        dt = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
        return arr.reshape(arr.shape[:-1] + (-1,)).ravel().view(dt).reshape(
            arr.shape[:-1])
    return arr


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _fsync_file(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str):
    # directory fsync flushes the entry metadata (the rename itself);
    # not all filesystems allow it — degrade silently rather than fail a save
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    names, leaves = _flatten_with_names(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    try:
        savable = [_to_savable(l) for l in leaves]
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **{f"a{i}": a for i, (a, _) in enumerate(savable)})
        meta = {"step": step, "names": names,
                "dtypes": [d for _, d in savable],
                "checksums": [_sha256(a) for a, _ in savable],
                "extra": extra or {}}
        meta_path = os.path.join(tmp, "meta.json")
        with open(meta_path, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        # durability before visibility: payload + meta bytes must be on disk
        # before the atomic rename publishes the step name
        _fsync_file(npz_path)
        _fsync_dir(tmp)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(ckpt_dir)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            try:
                out.append(int(d.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return sorted(out)


def verify_checkpoint(path: str) -> bool:
    """True iff ``path`` holds a complete, uncorrupted checkpoint.

    Checks: meta.json parses with the expected keys, arrays.npz exists and
    loads, every named array is present, and (when the meta carries them —
    pre-checksum checkpoints stay loadable) each array's SHA-256 matches.
    """
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        names = meta["names"]
        with np.load(os.path.join(path, "arrays.npz")) as data:
            arrays = [data[f"a{i}"] for i in range(len(names))]
        sums = meta.get("checksums")
        if sums is not None:
            if len(sums) != len(arrays):
                return False
            for want, arr in zip(sums, arrays):
                if _sha256(arr) != want:
                    return False
        return True
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *valid* step: corrupt/truncated step dirs are skipped with a
    warning (a crash mid-write or a damaged disk must degrade the rollback
    depth, not kill the restore)."""
    for step in reversed(_all_steps(ckpt_dir)):
        path = _step_path(ckpt_dir, step)
        if verify_checkpoint(path):
            return step
        warnings.warn(f"skipping corrupt/truncated checkpoint {path}; "
                      "falling back to the previous step")
    return None


def gc_checkpoints(ckpt_dir: str, keep: int,
                   protect: Iterable[int] = ()) -> list:
    """Retain the ``keep`` newest **valid** steps; returns deleted steps.

    Corrupt/truncated step dirs never count against the retention window
    (keeping a damaged step while collecting the newest restorable one
    would destroy the rollback anchor) and are themselves collected.  Steps
    in ``protect`` (e.g. the one a live resume replays from) are never
    collected, even when older than the retention window."""
    if keep < 1:
        raise ValueError("keep must be >= 1")
    steps = _all_steps(ckpt_dir)
    valid = [s for s in steps if verify_checkpoint(_step_path(ckpt_dir, s))]
    keep_set = set(valid[-keep:]) | set(int(s) for s in protect)
    doomed = [s for s in steps if s not in keep_set]
    for s in doomed:
        shutil.rmtree(_step_path(ckpt_dir, s), ignore_errors=True)
    return doomed


def load_checkpoint(ckpt_dir: str, tree_like, step: Optional[int] = None
                    ) -> Tuple[Any, dict]:
    """Restore into the structure of ``tree_like`` (names must match).

    ``step=None`` resolves to the newest valid step (corrupt dirs skipped,
    see :func:`latest_step`).  An *explicitly requested* step that fails
    verification raises — the caller named a specific rollback point and
    silently substituting another would break the bit-equality contract.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no (valid) checkpoints under {ckpt_dir}")
    path = _step_path(ckpt_dir, step)
    if not verify_checkpoint(path):
        raise ValueError(
            f"checkpoint {path} is corrupt or truncated (missing payload or "
            "SHA-256 mismatch); pass step=None to fall back to the newest "
            "valid step")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    names_now, _ = _flatten_with_names(tree_like)
    if names_now != meta["names"]:
        raise ValueError("checkpoint tree structure mismatch")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        leaves = [_from_savable(data[f"a{i}"], meta["dtypes"][i])
                  for i in range(len(meta["names"]))]
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
