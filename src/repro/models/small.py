"""Paper-scale models (MLP / ConvNet / tiny Transformer) for the faithful
TL reproduction — §4.1.2 of the paper.

These expose the *layer-split* API the TL protocol needs:
  first_layer(params, x)      -> X^(1)          (computed on the node)
  tail_layers(params, x1)     -> logits         (recomputed on the orchestrator)
  forward = tail_layers ∘ first_layer
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.paper_models import SmallModelConfig


def _dense(key, i, o):
    return {"w": jax.random.normal(key, (i, o)) / math.sqrt(i),
            "b": jnp.zeros((o,))}


def _apply_dense(p, x):
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------- MLP

def mlp_init(key, cfg: SmallModelConfig):
    dims = (int(jnp.prod(jnp.asarray(cfg.in_shape))),) + cfg.hidden + (cfg.n_classes,)
    keys = jax.random.split(key, len(dims) - 1)
    return {"layers": tuple(_dense(k, i, o)
                            for k, i, o in zip(keys, dims[:-1], dims[1:]))}


def mlp_first(params, x):
    x = x.reshape(x.shape[0], -1)
    return jax.nn.elu(_apply_dense(params["layers"][0], x))


def mlp_tail(params, h):
    for p in params["layers"][1:-1]:
        h = jax.nn.elu(_apply_dense(p, h))
    return _apply_dense(params["layers"][-1], h)


# ------------------------------------------------------------------ ConvNet

def conv_init(key, cfg: SmallModelConfig):
    chans = (cfg.in_shape[-1],) + cfg.conv_channels
    keys = jax.random.split(key, len(cfg.conv_channels) + len(cfg.hidden) + 1)
    convs = tuple(
        {"w": jax.random.normal(keys[i], (3, 3, chans[i], chans[i + 1]))
              / math.sqrt(9 * chans[i]),
         "b": jnp.zeros((chans[i + 1],))}
        for i in range(len(cfg.conv_channels)))
    side = cfg.in_shape[0] // (2 ** len(cfg.conv_channels))
    flat = side * side * chans[-1]
    dims = (flat,) + cfg.hidden + (cfg.n_classes,)
    dense = tuple(_dense(keys[len(convs) + j], dims[j], dims[j + 1])
                  for j in range(len(dims) - 1))
    return {"convs": convs, "dense": dense}


def _conv_block(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    y = jax.nn.relu(y)
    return jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def conv_first(params, x):
    return _conv_block(params["convs"][0], x)


def conv_tail(params, h):
    for p in params["convs"][1:]:
        h = _conv_block(p, h)
    h = h.reshape(h.shape[0], -1)
    for p in params["dense"][:-1]:
        h = jax.nn.relu(_apply_dense(p, h))
    return _apply_dense(params["dense"][-1], h)


# --------------------------------------------------------- tiny transformer

def tfm_init(key, cfg: SmallModelConfig):
    d, H, L = cfg.d_model, cfg.n_heads, cfg.n_layers
    ks = jax.random.split(key, 2 + 5 * L)
    params = {"embed": jax.random.normal(ks[0], (cfg.vocab_size, d)) * 0.02,
              "pos": jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02,
              "blocks": [], "out": None}
    blocks = []
    for l in range(L):
        o = 2 + 5 * l
        blocks.append({
            "wq": _dense(ks[o], d, d), "wk": _dense(ks[o + 1], d, d),
            "wv": _dense(ks[o + 2], d, d), "wo": _dense(ks[o + 3], d, d),
            "ff1": _dense(ks[o + 4], d, 4 * d),
            "ff2": _dense(jax.random.fold_in(ks[o + 4], 1), 4 * d, d),
        })
    params["blocks"] = tuple(blocks)
    params["out"] = _dense(jax.random.fold_in(key, 99), d, cfg.n_classes)
    return params


def _tfm_block(p, h, n_heads):
    B, S, d = h.shape
    hd = d // n_heads
    q = _apply_dense(p["wq"], h).reshape(B, S, n_heads, hd)
    k = _apply_dense(p["wk"], h).reshape(B, S, n_heads, hd)
    v = _apply_dense(p["wv"], h).reshape(B, S, n_heads, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S, d)
    h = h + _apply_dense(p["wo"], o)
    h = h + _apply_dense(p["ff2"], jax.nn.relu(_apply_dense(p["ff1"], h)))
    return h


def tfm_first(params, x, n_heads=4):
    """x: (B, S) int tokens."""
    h = params["embed"][x] + params["pos"][None, : x.shape[1]]
    return _tfm_block(params["blocks"][0], h, n_heads)


def tfm_tail(params, h, n_heads=4):
    for p in params["blocks"][1:]:
        h = _tfm_block(p, h, n_heads)
    return _apply_dense(params["out"], h.mean(axis=1))


# ------------------------------------------------------------------- facade

class SmallModel:
    """Split-forward classification model for the TL protocol."""

    def __init__(self, cfg: SmallModelConfig):
        self.cfg = cfg
        fam = cfg.family
        self._init = {"mlp": mlp_init, "conv": conv_init,
                      "transformer": tfm_init}[fam]
        if fam == "transformer":
            self.first_layer = lambda p, x: tfm_first(p, x, cfg.n_heads)
            self.tail_layers = lambda p, h: tfm_tail(p, h, cfg.n_heads)
        elif fam == "conv":
            self.first_layer, self.tail_layers = conv_first, conv_tail
        else:
            self.first_layer, self.tail_layers = mlp_first, mlp_tail

    def init(self, key):
        return self._init(key, self.cfg)

    def forward(self, params, x):
        return self.tail_layers(params, self.first_layer(params, x))

    def loss(self, params, x, y):
        logits = self.forward(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
