"""Decoder-only language model with scan-over-layer-cycles.

Layers are organized as ``prefix + n_cycles * pattern + suffix``:

* ``prefix``  — individually-parameterized layers (MoE archs put their
  ``first_k_dense`` dense-FFN layers here);
* ``cycles``  — the repeating block pattern (len 1 for uniform stacks,
  ("rglru","rglru","attn") for Griffin), parameters stacked on a leading
  cycle axis and executed with ``jax.lax.scan`` so compiled HLO size is
  O(pattern), not O(depth);
* ``suffix``  — pattern remainder (e.g. Griffin 38 = 12*3 + 2).

The Traversal-Learning split points are first-class:
``embed_tokens`` → ``block0`` (produces the paper's X^(1)) → ``tail``
(everything the orchestrator recomputes during centralized BP).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import dense_init, embed_init, rmsnorm, rmsnorm_init


# ------------------------------------------------------------------ planning

@dataclass(frozen=True)
class StackPlan:
    prefix: Tuple[int, ...]      # absolute layer indices
    n_cycles: int
    pattern: Tuple[str, ...]
    cycle_start: int             # absolute index of first scanned layer
    suffix: Tuple[int, ...]


def stack_plan(cfg: ModelConfig) -> StackPlan:
    patt = cfg.block_pattern or (("ssm",) if cfg.arch_type == "ssm" else ("attn",))
    n_prefix = cfg.moe.first_k_dense if cfg.moe is not None else 0
    remaining = cfg.n_layers - n_prefix
    n_cycles = remaining // len(patt)
    n_suffix = remaining % len(patt)
    return StackPlan(
        prefix=tuple(range(n_prefix)),
        n_cycles=n_cycles,
        pattern=patt,
        cycle_start=n_prefix,
        suffix=tuple(range(cfg.n_layers - n_suffix, cfg.n_layers)),
    )


# ---------------------------------------------------------------------- init

def _cycle_block_init(key, cfg, kind, layer_idx, dtype):
    return blocks.block_init(key, cfg, kind, blocks.ffn_kind(cfg, layer_idx),
                             dtype=dtype)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    plan = stack_plan(cfg)
    n_keys = 4 + len(plan.prefix) + len(plan.suffix) + 1
    ks = list(jax.random.split(key, n_keys))
    p: dict = {"embed": embed_init(ks.pop(), cfg.vocab_size, cfg.d_model, dtype),
               "final_norm": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks.pop(), cfg.d_model, cfg.vocab_size, dtype)

    p["prefix"] = tuple(
        blocks.block_init(ks.pop(), cfg, cfg.pattern[i], blocks.ffn_kind(cfg, i),
                          dtype=dtype)
        for i in plan.prefix)
    p["suffix"] = tuple(
        blocks.block_init(ks.pop(), cfg, cfg.pattern[i], blocks.ffn_kind(cfg, i),
                          dtype=dtype)
        for i in plan.suffix)

    if plan.n_cycles:
        def one_cycle(ck):
            cks = jax.random.split(ck, len(plan.pattern))
            return tuple(
                _cycle_block_init(cks[j], cfg, plan.pattern[j],
                                  plan.cycle_start + j, dtype)
                for j in range(len(plan.pattern)))
        cycle_keys = jax.random.split(ks.pop(), plan.n_cycles)
        p["cycles"] = jax.vmap(one_cycle)(cycle_keys)
    else:
        p["cycles"] = ()

    if cfg.mtp_depth:
        km = ks.pop()
        k1, k2, k3 = jax.random.split(km, 3)
        p["mtp"] = {
            "proj": dense_init(k1, 2 * cfg.d_model, cfg.d_model, dtype),
            "norm_h": rmsnorm_init(cfg.d_model, dtype),
            "norm_e": rmsnorm_init(cfg.d_model, dtype),
            "block": blocks.block_init(
                k2, cfg, "attn",
                "dense" if cfg.d_ff else "none", dtype=dtype),
        }
    return p


# ------------------------------------------------------------------- forward

def _ffn_kinds_for_cycle(cfg, plan):
    return tuple(blocks.ffn_kind(cfg, plan.cycle_start + j)
                 for j in range(len(plan.pattern)))


def _apply_cycle(cycle_params, cfg, plan, h, caches=None, cache_len=None,
                 positions=None, skip_first: int = 0):
    """Apply one pattern cycle; caches is a tuple aligned with pattern."""
    kinds = plan.pattern
    ffns = _ffn_kinds_for_cycle(cfg, plan)
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for j in range(skip_first, len(kinds)):
        c = None if caches is None else caches[j]
        h, nc, a = blocks.block_apply(cycle_params[j], cfg, kinds[j], ffns[j], h,
                                      cache=c, cache_len=cache_len,
                                      positions=positions)
        new_caches.append(nc)
        aux = aux + a
    return h, tuple(new_caches), aux


def run_stack(params, cfg: ModelConfig, h, *, caches=None, cache_len=None,
              positions=None, skip_block0: bool = False):
    """Run all blocks.  Returns (h, new_caches, aux).

    ``skip_block0=True`` starts execution *after* the first block — the
    Traversal-Learning tail (orchestrator recompute) entry point.
    """
    plan = stack_plan(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_prefix, new_cycle_caches, new_suffix = [], None, []
    first_in_cycle0 = 0

    # ---- prefix
    prefix = params["prefix"]
    start = 1 if (skip_block0 and prefix) else 0
    if skip_block0 and not prefix:
        first_in_cycle0 = 1
    for i, bp in enumerate(prefix):
        if i < start:
            new_prefix.append(None if caches is None else caches["prefix"][i])
            continue
        li = plan.prefix[i]
        c = None if caches is None else caches["prefix"][i]
        h, nc, a = blocks.block_apply(bp, cfg, cfg.pattern[li],
                                      blocks.ffn_kind(cfg, li), h, cache=c,
                                      cache_len=cache_len, positions=positions)
        new_prefix.append(nc)
        aux = aux + a

    # ---- scanned cycles
    if plan.n_cycles:
        cyc = params["cycles"]
        cyc_caches = None if caches is None else caches["cycles"]
        if first_in_cycle0:
            # cycle 0 runs partially (block 0 skipped), outside the scan
            c0 = jax.tree.map(lambda x: x[0], cyc)
            cc0 = None if cyc_caches is None else jax.tree.map(
                lambda x: x[0], cyc_caches)
            h, nc0, a = _apply_cycle(c0, cfg, plan, h, cc0, cache_len,
                                     positions, skip_first=1)
            aux = aux + a
            rest = jax.tree.map(lambda x: x[1:], cyc)
            rest_caches = None if cyc_caches is None else jax.tree.map(
                lambda x: x[1:], cyc_caches)
            n_scan = plan.n_cycles - 1
        else:
            nc0 = None
            rest, rest_caches, n_scan = cyc, cyc_caches, plan.n_cycles

        if n_scan:
            def scan_body(carry, xs):
                hh, ax = carry
                cp, cc = xs
                hh, ncs, a = _apply_cycle(cp, cfg, plan, hh, cc, cache_len,
                                          positions)
                return (hh, ax + a), ncs

            if rest_caches is None:
                def scan_body_nocache(carry, cp):
                    hh, ax = carry
                    hh, _, a = _apply_cycle(cp, cfg, plan, hh, None, cache_len,
                                            positions)
                    return (hh, ax + a), None

                (h, aux), _ = jax.lax.scan(scan_body_nocache, (h, aux), rest)
                scanned_caches = None
            else:
                (h, aux), scanned_caches = jax.lax.scan(
                    scan_body, (h, aux), (rest, rest_caches))
        else:
            scanned_caches = rest_caches

        if caches is not None:
            if first_in_cycle0:
                # stitch partial cycle-0 cache back on top of scanned caches
                def stitch(c0_leaf, rest_leaf):
                    return jnp.concatenate([c0_leaf[None], rest_leaf], axis=0)
                # nc0 omits the skipped block; reuse its old cache slice
                old0 = jax.tree.map(lambda x: x[0], cyc_caches)
                full0 = (old0[0],) + nc0
                new_cycle_caches = jax.tree.map(stitch, full0, scanned_caches) \
                    if scanned_caches is not None else jax.tree.map(
                        lambda x: x[None], full0)
            else:
                new_cycle_caches = scanned_caches

    # ---- suffix
    for i, bp in enumerate(params["suffix"]):
        li = plan.suffix[i]
        c = None if caches is None else caches["suffix"][i]
        h, nc, a = blocks.block_apply(bp, cfg, cfg.pattern[li],
                                      blocks.ffn_kind(cfg, li), h, cache=c,
                                      cache_len=cache_len, positions=positions)
        new_suffix.append(nc)
        aux = aux + a

    new_caches = None
    if caches is not None:
        new_caches = {"prefix": tuple(new_prefix), "cycles": new_cycle_caches,
                      "suffix": tuple(new_suffix)}
    return h, new_caches, aux


# ------------------------------------------------------------ public surface

def embed_tokens(params, cfg: ModelConfig, tokens, extra_embeds=None):
    """tokens: (B, S) int32.  extra_embeds: (B, F, d) frontend stub output,
    prepended to the sequence (VLM patches / audio frames)."""
    h = params["embed"][tokens] * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)
                                           ).astype(params["embed"].dtype)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    return h


def block0(params, cfg: ModelConfig, h):
    """First block — produces the TL first-layer activations X^(1)."""
    plan = stack_plan(cfg)
    if params["prefix"]:
        bp, li = params["prefix"][0], 0
    else:
        bp, li = jax.tree.map(lambda x: x[0], params["cycles"])[0], plan.cycle_start
    h, _, aux = blocks.block_apply(bp, cfg, cfg.pattern[li],
                                   blocks.ffn_kind(cfg, li), h)
    return h, aux


def tail(params, cfg: ModelConfig, h1, return_hidden: bool = False):
    """Blocks 1..L-1 + final norm + head: what TL's orchestrator recomputes."""
    h, _, aux = run_stack(params, cfg, h1, skip_block0=True)
    if return_hidden:
        return _logits(params, cfg, h), h, aux
    return _logits(params, cfg, h), aux


def _logits(params, cfg: ModelConfig, h):
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", h, head)


def forward(params, cfg: ModelConfig, tokens, extra_embeds=None, positions=None):
    """Full forward.  Returns (logits, aux_loss)."""
    h = embed_tokens(params, cfg, tokens, extra_embeds)
    h, _, aux = run_stack(params, cfg, h, positions=positions)
    return _logits(params, cfg, h), aux


def mtp_logits(params, cfg: ModelConfig, tokens, h_final):
    """DeepSeek-V3 multi-token-prediction head (depth 1): predict t+2 from the
    final hidden state at t combined with the embedding of token t+1."""
    m = params["mtp"]
    emb_next = params["embed"][tokens] * jnp.sqrt(
        jnp.asarray(cfg.d_model, jnp.float32)).astype(params["embed"].dtype)
    # shift: position t sees embedding of token t+1
    emb_next = jnp.roll(emb_next, -1, axis=1)
    z = jnp.concatenate([rmsnorm(m["norm_h"], h_final, cfg.norm_eps),
                         rmsnorm(m["norm_e"], emb_next, cfg.norm_eps)], axis=-1)
    z = jnp.einsum("bse,ed->bsd", z, m["proj"])
    z, _, _ = blocks.block_apply(m["block"], cfg, "attn",
                                 "dense" if cfg.d_ff else "none", z)
    return _logits(params, cfg, z)


def forward_with_hidden(params, cfg: ModelConfig, tokens, extra_embeds=None,
                        positions=None):
    h = embed_tokens(params, cfg, tokens, extra_embeds)
    h, _, aux = run_stack(params, cfg, h, positions=positions)
    return _logits(params, cfg, h), h, aux


# --------------------------------------------------------------------- cache

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    plan = stack_plan(cfg)
    pref = tuple(blocks.block_cache_init(cfg, cfg.pattern[i], batch, max_len, dtype)
                 for i in plan.prefix)
    suff = tuple(blocks.block_cache_init(cfg, cfg.pattern[i], batch, max_len, dtype)
                 for i in plan.suffix)
    if plan.n_cycles:
        one = tuple(blocks.block_cache_init(cfg, k, batch, max_len, dtype)
                    for k in plan.pattern)
        cyc = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (plan.n_cycles,) + x.shape), one)
    else:
        cyc = None
    return {"prefix": pref, "cycles": cyc, "suffix": suff}


def prefill(params, cfg: ModelConfig, caches, tokens, extra_embeds=None):
    """Production prefill: fill the KV caches for the whole prompt and return
    only the last position's logits (never materializes (B, S, V))."""
    h = embed_tokens(params, cfg, tokens, extra_embeds)
    h, new_caches, _ = run_stack(params, cfg, h, caches=caches,
                                 cache_len=jnp.asarray(0, jnp.int32))
    return _logits(params, cfg, h[:, -1:])[:, 0], new_caches


def decode_step(params, cfg: ModelConfig, caches, token, cache_len,
                positions=None):
    """One decode step.  token: (B,) int32; cache_len: scalar int32 (tokens
    already in cache).  Returns (logits (B, V), new_caches)."""
    h = embed_tokens(params, cfg, token[:, None])
    h, new_caches, _ = run_stack(params, cfg, h, caches=caches,
                                 cache_len=cache_len, positions=positions)
    return _logits(params, cfg, h)[:, 0], new_caches
