"""Mamba-2 block with SSD (state-space duality) mixing. [arXiv:2405.21060]

The SSD scan is the chunked formulation: within a chunk attention-like
(quadratic in chunk size), across chunks a linear recurrence over the
(heads, head_dim, d_state) state.  ``repro.kernels.ssd`` is the Pallas TPU
kernel for the same computation; this module uses the XLA-native chunked
path so it lowers on any backend.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (causal_conv1d, causal_conv1d_init,
                                 causal_conv1d_step, dense_init, rmsnorm,
                                 rmsnorm_init)


# ------------------------------------------------------------ chunked SSD op

def ssd_chunked(x, dt, A_log, Bmat, Cmat, chunk: int):
    """Chunked SSD scan.

    x:  (B, S, H, P)   inputs (already conv'd/activated)
    dt: (B, S, H)      softplus'd timestep
    A_log: (H,)        state decay log (A = -exp(A_log))
    Bmat, Cmat: (B, S, N)  shared across heads (ngroups=1)
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "sequence must be divisible by chunk"

    A = -jnp.exp(A_log.astype(jnp.float32))                     # (H,)
    dA = dt.astype(jnp.float32) * A                             # (B,S,H)  log-decay
    xdt = x.astype(jnp.float32) * dt[..., None]                 # dt-scaled input

    # reshape into chunks
    c = lambda t: t.reshape(B, nc, chunk, *t.shape[2:])
    xc, dAc = c(xdt), c(dA)
    Bc, Cc = c(Bmat.astype(jnp.float32)), c(Cmat.astype(jnp.float32))

    seg = jnp.cumsum(dAc, axis=2)                               # (B,nc,ck,H)
    # intra-chunk (quadratic within chunk): decay(t,s) = exp(seg_t - seg_s), s<=t
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]         # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp(rel) overflows for s>t and inf*0 NaNs the backward
    rel = jnp.where(tri[None, None, :, :, None], rel, -1e9)
    decay = jnp.exp(rel)
    scores = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)              # (B,nc,t,s)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", scores, decay, xc)

    # chunk summary states: state_c = sum_s exp(seg_end - seg_s) * x_s B_s^T
    decay_end = jnp.exp(seg[:, :, -1:, :] - seg)                # (B,nc,ck,H)
    states = jnp.einsum("bcsh,bcshp,bcsn->bchpn", decay_end, xc, Bc)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(seg[:, :, -1, :])                     # (B,nc,H)

    def step(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h                                          # emit state *before* chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    hT, h_before = jax.lax.scan(
        step,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)

    # inter-chunk contribution: y_t += C_t . (decay(t,start) * h_before)
    decay_in = jnp.exp(seg)                                     # (B,nc,ck,H)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", Cc, decay_in, h_before)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(x.dtype), hT


def ssd_ref(x, dt, A_log, Bmat, Cmat):
    """O(S^2) reference (naive materialized) — used by tests as oracle."""
    B, S, H, P = x.shape
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = dt.astype(jnp.float32) * A
    seg = jnp.cumsum(dA, axis=1)                                # (B,S,H)
    rel = seg[:, :, None, :] - seg[:, None, :, :]               # (B,t,s,H)
    tri = jnp.tril(jnp.ones((S, S), bool))
    decay = jnp.exp(jnp.where(tri[None, :, :, None], rel, -1e9))
    scores = jnp.einsum("btn,bsn->bts", Cmat.astype(jnp.float32),
                        Bmat.astype(jnp.float32))
    xdt = x.astype(jnp.float32) * dt[..., None]
    y = jnp.einsum("bts,btsh,bshp->bthp", scores, decay, xdt)
    return y.astype(x.dtype)


# -------------------------------------------------------------- Mamba2 block

def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * s.d_state                # conv over [x, B, C]
    return {
        # fused input projection -> [z, x, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * di + 2 * s.d_state + H, dtype),
        "conv": causal_conv1d_init(ks[1], conv_ch, s.conv_kernel, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": rmsnorm_init(di, dtype),
        "w_out": dense_init(ks[2], di, d, dtype),
    }


def _split_in(proj, di, N, H):
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + N]
    Cm = proj[..., 2 * di + N:2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, x, Bm, Cm, dt


def mamba2_apply(params, cfg: ModelConfig, x, *, cache=None, cache_len=None,
                 positions=None):
    """x: (B,S,d). cache: {"conv": (B,k-1,conv_ch), "state": (B,H,P,N)}."""
    s = cfg.ssm
    B, S, d = x.shape
    di, N, H, P = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xs, Bm, Cm, dt = _split_in(proj, di, N, H)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)

    if cache is None or S > 1:
        # full scan (training, or prefill-from-empty when a cache is given)
        conv_out = jax.nn.silu(causal_conv1d(params["conv"], conv_in))
        xs, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + N],
                      conv_out[..., di + N:])
        dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
        xh = xs.reshape(B, S, H, P)
        pad = (-S) % s.chunk_size
        if pad:
            # pad with dt=0, x=0: decay exp(0·A)=1 and zero input, so the
            # final state hT passes through padding unchanged (exact)
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, hT = ssd_chunked(xh, dt, params["A_log"], Bm, Cm, s.chunk_size)
        y = y[:, :S]
        y = y + params["D"][None, None, :, None] * xh[:, :S]
        new_cache = None
        if cache is not None:
            k = s.conv_kernel - 1
            new_cache = {"conv": conv_in[:, -k:].astype(cache["conv"].dtype),
                         "state": hT}
        y = y.reshape(B, S, di).astype(x.dtype)   # keep dtype scan-stable
    else:
        # decode: one step through conv state + SSM state
        conv_state, ssm_state = cache["conv"], cache["state"]
        conv_state, conv_out = causal_conv1d_step(params["conv"], conv_state,
                                                  conv_in[:, 0])
        conv_out = jax.nn.silu(conv_out)
        xs1, Bm1, Cm1 = (conv_out[..., :di], conv_out[..., di:di + N],
                         conv_out[..., di + N:])
        dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
        xh = xs1.reshape(B, H, P)
        A = -jnp.exp(params["A_log"])
        decay = jnp.exp(dt1 * A)                                 # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xh * dt1[..., None], Bm1.astype(xh.dtype))
        ssm_state = ssm_state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", ssm_state, Cm1.astype(ssm_state.dtype))
        y = y + params["D"][None, :, None] * xh
        y = y.reshape(B, 1, di).astype(x.dtype)
        new_cache = {"conv": conv_state, "state": ssm_state}

    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), new_cache


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di, N, H, P = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, di + 2 * N), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }
