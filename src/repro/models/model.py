"""Unified model facade: build once from a ModelConfig, use everywhere.

API (all pure functions closed over the config):
  m = build_model(cfg)
  params = m.init(key, dtype)
  logits, aux = m.forward(params, tokens, extra_embeds=None)
  loss, metrics = m.loss(params, batch)
  cache = m.init_cache(batch, max_len, dtype)
  logits, cache = m.decode_step(params, cache, token, cache_len)
  h1 = m.block0(params, m.embed(params, tokens))      # TL split points
  logits, aux = m.tail(params, h1)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


def cross_entropy(logits, targets, mask=None):
    """Mean next-token CE.  logits: (B,S,V); targets: (B,S) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable            # (params, tokens, extra_embeds=None) -> (logits, aux)
    loss: Callable               # (params, batch) -> (scalar, metrics)
    init_cache: Callable         # (batch, max_len, dtype) -> cache
    decode_step: Callable        # (params, cache, token, cache_len) -> (logits, cache)
    prefill: Callable = None     # (params, cache, tokens, extra) -> (logits, cache)
    embed: Callable = None
    block0: Callable = None
    tail: Callable = None


MTP_WEIGHT = 0.3


def mtp_shift_targets(targets):
    """MTP scores token t+2: shift targets left by one more step and mask
    the last two positions, whose t+2 targets fall off the sequence.
    Returns ``(t2, valid)`` for :func:`cross_entropy`."""
    t2 = jnp.roll(targets, -1, axis=1)
    valid = jnp.ones_like(t2).at[:, -2:].set(0)
    return t2, valid


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encdec:
        return _build_encdec(cfg)
    return _build_decoder_lm(cfg)


def _build_decoder_lm(cfg: ModelConfig) -> Model:
    F = cfg.frontend_tokens if cfg.frontend else 0

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        targets = batch["targets"]
        extra = batch.get("embeds")
        if cfg.mtp_depth:
            logits, h, aux = transformer.forward_with_hidden(
                params, cfg, tokens, extra)
        else:
            logits, aux = transformer.forward(params, cfg, tokens, extra)
            h = None
        # frontend positions are not scored
        logits_txt = logits[:, F:] if F else logits
        mask = batch.get("mask")
        ce = cross_entropy(logits_txt, targets, mask)
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth:
            h_txt = h[:, F:] if F else h
            mtp = transformer.mtp_logits(params, cfg, tokens, h_txt)
            t2, valid = mtp_shift_targets(targets)
            mtp_ce = cross_entropy(mtp, t2, valid)
            total = total + MTP_WEIGHT * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: transformer.init_params(key, cfg, dtype),
        forward=lambda p, tokens, extra_embeds=None, positions=None:
            transformer.forward(p, cfg, tokens, extra_embeds, positions),
        loss=loss_fn,
        init_cache=lambda batch, max_len, dtype=jnp.float32:
            transformer.init_cache(cfg, batch, max_len, dtype),
        decode_step=lambda p, cache, token, cache_len:
            transformer.decode_step(p, cfg, cache, token, cache_len),
        prefill=lambda p, cache, tokens, extra_embeds=None:
            transformer.prefill(p, cfg, cache, tokens, extra_embeds),
        embed=lambda p, tokens, extra_embeds=None:
            transformer.embed_tokens(p, cfg, tokens, extra_embeds),
        block0=lambda p, h: transformer.block0(p, cfg, h)[0],
        tail=lambda p, h1: transformer.tail(p, cfg, h1),
    )


def _build_encdec(cfg: ModelConfig) -> Model:
    def loss_fn(params, batch):
        logits, aux = encdec.forward(params, cfg, batch["tokens"],
                                     batch.get("embeds"))
        ce = cross_entropy(logits, batch["targets"], batch.get("mask"))
        return ce + aux, {"ce": ce, "aux": aux, "loss": ce + aux}

    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: encdec.init_params(key, cfg, dtype),
        forward=lambda p, tokens, extra_embeds=None, positions=None:
            encdec.forward(p, cfg, tokens, extra_embeds, positions),
        loss=loss_fn,
        init_cache=lambda batch, max_len, dtype=jnp.float32:
            encdec.init_cache(cfg, batch, max_len, dtype),
        decode_step=lambda p, cache, token, cache_len:
            encdec.decode_step(p, cfg, cache, token, cache_len),
        prefill=lambda p, cache, tokens, extra_embeds=None:
            encdec.prefill(p, cfg, cache, tokens, extra_embeds),
    )
