"""Mixture-of-experts FFN (DeepSeek-style: shared + routed top-k).

Dispatch is *sort-free scatter/gather with per-group capacity* — the
TPU-native expert-parallel layout:

  tokens are grouped along the batch dim (groups shard on the ``data`` mesh
  axis); within a group each token's top-k choices receive a slot
  ``(expert, rank)`` where rank = #earlier tokens in the group that chose the
  same expert.  Tokens overflowing ``capacity`` are dropped (their combine
  weight contribution is zero), matching capacity-factor routing used by
  GSPMD MoE systems.  The expert FFN then runs as one batched einsum over
  ``(groups, experts, capacity, d)`` with experts sharded on the ``model``
  axis — gather/scatter carries the all-to-all, the einsum carries the FLOPs
  (so cost_analysis reports *active* FLOPs only).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, swiglu, swiglu_init


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    k_router, k_exp, k_shared = jax.random.split(key, 3)
    d, dff = cfg.d_model, m.d_ff_expert
    ek = jax.random.split(k_exp, 3)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(k_router, d, m.n_routed_experts, dtype),
        # experts stacked on a leading E axis (shards on the "model" mesh axis)
        "w_gate": (jax.random.normal(ek[0], (m.n_routed_experts, d, dff)) * std).astype(dtype),
        "w_up": (jax.random.normal(ek[1], (m.n_routed_experts, d, dff)) * std).astype(dtype),
        "w_down": (jax.random.normal(ek[2], (m.n_routed_experts, dff, d))
                   * (1.0 / math.sqrt(dff))).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = swiglu_init(k_shared, d, dff * m.n_shared_experts, dtype)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(tokens_per_group * m.top_k * m.capacity_factor
                        / m.n_routed_experts))
    return max(cap, m.top_k if tokens_per_group == 1 else 1)


# When set (via set_expert_parallel_mesh), moe_apply delegates to the
# shard_map expert-parallel path (models/moe_ep.py) — the §Perf beyond-paper
# dispatch with exactly two all_to_all per layer.
_EP_MESH = None


def set_expert_parallel_mesh(mesh):
    global _EP_MESH
    _EP_MESH = mesh


def moe_apply(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (out, aux_loss).  Groups = batch rows."""
    if _EP_MESH is not None:
        from repro.models.moe_ep import moe_apply_ep
        from repro.dist.sharding import batch_axes
        return moe_apply_ep(params, cfg, x, _EP_MESH,
                            data_axis=tuple(batch_axes(_EP_MESH)))
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_routed_experts, m.top_k
    C = _capacity(S, cfg)
    xt = x.reshape(B, S, d)

    logits = jnp.einsum("gtd,de->gte", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                     # (G,T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)   # renormalize top-k

    # ---- auxiliary load-balance loss (DeepSeek eq. style: E * mean f_i P_i)
    one_hot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)     # (G,T,k,E)
    f = one_hot.sum(axis=2).mean(axis=1)                           # (G,E) token frac * k
    P = probs.mean(axis=1)                                         # (G,E)
    aux = (E * (f / k * P).sum(-1)).mean() * m.router_aux_weight

    # ---- slot assignment: rank of each (token, choice) within its expert.
    # Sort-based ranking: stable argsort by expert id gives (expert, token)
    # order, so rank-within-expert = sorted position - expert segment start —
    # identical semantics to a one-hot cumsum (earlier tokens win slots) but
    # O(T·k·log) instead of an O(T·k·E) materialized buffer per layer.
    flat_e = expert_idx.reshape(B, S * k)                          # (G, T*k)
    order = jnp.argsort(flat_e, axis=1, stable=True)               # (G, T*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    counts = jnp.zeros((B, E), jnp.int32).at[
        jnp.arange(B)[:, None], flat_e].add(1)                     # (G, E)
    starts = jnp.cumsum(counts, axis=1) - counts                   # exclusive
    rank_sorted = jnp.arange(S * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1)                                  # (G, T*k)
    pos = jnp.zeros_like(flat_e).at[
        jnp.arange(B)[:, None], order].set(rank_sorted)            # unsort
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)                # overflow -> sink row

    # ---- dispatch: scatter token copies into (E*C+1, d) buffers per group
    x_rep = jnp.repeat(xt, k, axis=1)                              # (G, T*k, d)
    buf = jnp.zeros((B, E * C + 1, d), xt.dtype)
    buf = buf.at[jnp.arange(B)[:, None], slot].add(x_rep)
    expert_in = buf[:, : E * C].reshape(B, E, C, d)

    # ---- expert FFN: batched swiglu over (G, E, C, d)
    g = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])

    # ---- combine: gather each choice's slot output, weight by gate
    out_buf = expert_out.reshape(B, E * C, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((B, 1, d), out_buf.dtype)], axis=1)
    gathered = out_buf[jnp.arange(B)[:, None], slot]               # (G, T*k, d)
    w = (gate.reshape(B, S * k) * keep).astype(gathered.dtype)
    combined = (gathered * w[..., None]).reshape(B, S, k, d).sum(axis=2)

    if m.n_shared_experts:
        combined = combined + swiglu(params["shared"], x)
    return combined, aux
