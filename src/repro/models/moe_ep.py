"""Expert-parallel MoE via shard_map + explicit all_to_all (beyond-paper).

The GSPMD path (`moe.moe_apply`) lets the partitioner infer collectives for
the dispatch scatter/gather — correct, but the §Perf analysis showed it can
pick pessimal layouts.  This module is the hand-scheduled alternative used
by real EP systems:

  per (data, model) shard, locally:
    route -> build per-destination-shard buffers (TP, E_local, C, d)
  all_to_all over the model axis          (tokens travel to expert owners)
  local expert FFN over (E_local, TP*C, d)
  all_to_all back                         (results return to token owners)
  local combine with the saved slot map   (no metadata exchange: the return
                                           trip preserves the send layout)

Exactly two all_to_all collectives per MoE layer, each of
``tokens_local · top_k · d`` bytes — the information-theoretic minimum for
capacity routing.  Differentiable (all_to_all transposes to all_to_all).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import swiglu


def _local_route(x_flat, router_w, cfg: ModelConfig, tp: int, cap: int):
    """Route local tokens; build per-destination buffers and the slot map.

    x_flat: (T, d).  Returns (buffers (tp, E_loc, cap, d), slot map (T, k),
    gates (T, k), keep (T, k), aux).
    """
    m = cfg.moe
    E, k = m.n_routed_experts, m.top_k
    E_loc = E // tp
    T, d = x_flat.shape

    logits = (x_flat @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    one_hot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    f = one_hot.sum(axis=1).mean(axis=0)
    aux = (E * (f / k * probs.mean(axis=0)).sum()) * m.router_aux_weight

    # rank within (expert) over the local tokens — stable sort, token-major
    flat_e = expert_idx.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    rank = jnp.zeros_like(flat_e).at[order].set(rank_sorted)    # (T*k,)

    keep = (rank < cap).reshape(T, k)
    dest = flat_e // E_loc                                      # target shard
    e_loc = flat_e % E_loc
    slot = jnp.where(rank < cap,
                     (dest * E_loc + e_loc) * cap + rank,
                     tp * E_loc * cap)                          # sink row
    buf = jnp.zeros((tp * E_loc * cap + 1, d), x_flat.dtype)
    buf = buf.at[slot].add(jnp.repeat(x_flat, k, axis=0))
    return (buf[:-1].reshape(tp, E_loc, cap, d), slot.reshape(T, k),
            gate.astype(x_flat.dtype), keep, aux)


def moe_apply_ep(params, cfg: ModelConfig, x, mesh: Mesh, *,
                 model_axis: str = "model", data_axis=("data",)):
    """Drop-in for ``moe.moe_apply`` with explicit expert parallelism.

    x: (B, S, d).  Must be called under ``mesh``; batch is expected sharded
    over ``data_axis``, experts shard over ``model_axis``.
    """
    m = cfg.moe
    tp = int(mesh.shape[model_axis])
    E, k = m.n_routed_experts, m.top_k
    assert E % tp == 0, "experts must divide the model axis"
    B, S, d = x.shape
    n_data = 1
    for a in data_axis:
        n_data *= int(mesh.shape[a])
    # tokens shard over data (batch) AND model (sequence): every chip routes
    # only its own slice — without this, all tp model-chips of a data row
    # dispatch the same tokens redundantly (tp× wasted expert compute)
    seq_shard = tp if S % tp == 0 else 1
    T_loc = (B // n_data) * (S // seq_shard)
    cap = max(1, int(math.ceil(T_loc * k * m.capacity_factor / E)))

    def local_fn(x_loc, router_w, w_gate, w_up, w_down):
        # x_loc: (B_loc, S_loc, d); expert weights: (E_loc, ...) local slices
        Bl, Sl = x_loc.shape[0], x_loc.shape[1]
        x_flat = x_loc.reshape(Bl * Sl, d)
        buf, slot, gate, keep, aux = _local_route(x_flat, router_w, cfg, tp,
                                                  cap)
        # tokens -> expert owners (split dim0 across model, gather sources)
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)    # (tp,E_loc,cap,d)
        h_in = recv.transpose(1, 0, 2, 3).reshape(
            recv.shape[1], tp * cap, d)                         # (E_loc, tp*cap, d)
        g = jnp.einsum("ecd,edf->ecf", h_in, w_gate)
        u = jnp.einsum("ecd,edf->ecf", h_in, w_up)
        h_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)
        # results -> token owners (same layout back)
        send = h_out.reshape(recv.shape[1], tp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)    # (tp,E_loc,cap,d)
        out_buf = jnp.concatenate(
            [back.reshape(tp * back.shape[1] * cap, d),
             jnp.zeros((1, d), back.dtype)], axis=0)
        gathered = out_buf[slot.reshape(-1)].reshape(Bl * Sl, k, d)
        w = (gate * keep).astype(gathered.dtype)
        y = (gathered * w[..., None]).sum(axis=1).reshape(Bl, Sl, d)
        # aux is a local mean over this shard's tokens; average over shards
        aux_mean = jax.lax.pmean(aux, axis_name=model_axis)
        for a in data_axis:
            aux_mean = jax.lax.pmean(aux_mean, axis_name=a)
        return y, aux_mean

    dp = data_axis if len(data_axis) > 1 else data_axis[0]
    dspec = P(dp, model_axis if seq_shard > 1 else None, None)
    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(dspec, P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(dspec, P()),
        check_rep=False,
    )(x, params["router"].astype(x.dtype), params["w_gate"], params["w_up"],
      params["w_down"])
    y, aux = out
    if m.n_shared_experts:
        y = y + swiglu(params["shared"], x)
    return y, aux
