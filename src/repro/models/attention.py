"""Attention variants: MHA / GQA (+QKV bias), MLA (DeepSeek latent), sliding window.

Three execution paths share one math definition:
  * dense path      — materialized scores, for short sequences / CPU tests;
  * blockwise path  — ``lax.scan`` over KV blocks with online softmax (an
    XLA-native flash attention used for long-sequence lowering; the Pallas
    kernel in ``repro.kernels.flash_attention`` is the TPU runtime analogue);
  * decode path     — single query token against a KV cache.

MLA is evaluated in *latent* form: queries are absorbed into the kv_lora
latent space, so the KV cache stores only (c_kv, k_rope) per token (MQA-like),
which is the memory saving that defines MLA.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, dense_init,
                                 rmsnorm, rmsnorm_init)

NEG_INF = -1e30
DENSE_MAX_SEQ = 2048        # use the blockwise path above this length
KV_BLOCK = 1024


# =============================================================== param init

def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        ks = jax.random.split(key, 8)
        return {
            "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
            "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
            "w_uq": dense_init(ks[1], m.q_lora_rank,
                               H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype),
            "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
            "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
            "w_kr": dense_init(ks[3], d, m.qk_rope_head_dim, dtype),
            "w_uk": dense_init(ks[4], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
            "w_uv": dense_init(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype),
            "w_o": dense_init(ks[6], H * m.v_head_dim, d, dtype),
        }
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], d, H * hd, dtype),
        "w_k": dense_init(ks[1], d, KV * hd, dtype),
        "w_v": dense_init(ks[2], d, KV * hd, dtype),
        "w_o": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((H * hd,), dtype)
        p["b_k"] = jnp.zeros((KV * hd,), dtype)
        p["b_v"] = jnp.zeros((KV * hd,), dtype)
    return p


# ========================================================== core attention op

def _mask_bias(q_pos, k_pos, window: int):
    """Causal (+ optional sliding-window) additive bias. Shapes broadcast."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        causal &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(causal, 0.0, NEG_INF)


def attend_dense(q, k, v, q_pos, k_pos, window: int, scale: float):
    """q: (B,Sq,H,dh) k,v: (B,Sk,KV,dv*). Returns (B,Sq,H,dv).

    GQA without materializing repeated K/V: q-heads are grouped per kv-head
    (reshape, not repeat) so cache reads stay at KV-head volume.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, dh)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32) * scale
    scores = scores + _mask_bias(q_pos, k_pos, window)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def attend_blockwise(q, k, v, q_pos, k_pos, window: int, scale: float,
                     block: int = KV_BLOCK):
    """Online-softmax attention scanning KV blocks (flash-style in XLA).

    Memory is O(Sq * block) instead of O(Sq * Sk).  Matches ``attend_dense``
    to float tolerance (tests assert this).
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    dv = v.shape[-1]
    nb = -(-Sk // block)
    pad = nb * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(B, nb, block, KV, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, KV, dv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nb, block)
    qg = q.astype(jnp.float32).reshape(B, Sq, KV, rep, dh)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, pblk = xs
        # GQA grouped (no repeated K/V materialization)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg,
                       kblk.astype(jnp.float32)) * scale
        s = s + _mask_bias(q_pos, pblk, window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KV, rep, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,rep,Sq,dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dv).astype(v.dtype)


def attend(q, k, v, q_pos, k_pos, window: int, scale: float):
    if k.shape[1] <= DENSE_MAX_SEQ or q.shape[1] == 1:
        return attend_dense(q, k, v, q_pos, k_pos, window, scale)
    return attend_blockwise(q, k, v, q_pos, k_pos, window, scale)


# ================================================================= GQA / MHA

def _positions(pos0, S, B):
    return pos0 + jnp.arange(S, dtype=jnp.int32)


def gqa_project(params, cfg: ModelConfig, x, q_pos, *, positions=None):
    """Project x → rope'd (q, k, v).  q_pos: (B, S) absolute positions.

    Shared by the oracle paths below and the paged-KV serving runner
    (``repro.serve.runner``) so both produce bit-identical projections.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["w_q"])
    k = jnp.einsum("bsd,de->bse", x, params["w_k"])
    v = jnp.einsum("bsd,de->bse", x, params["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.rope == "rope":
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
    elif cfg.rope == "mrope":
        p3 = (jnp.broadcast_to(q_pos, (3, B, S))
              if positions is None else positions)
        q = apply_mrope(q, p3, cfg.rope_theta)
        k = apply_mrope(k, p3, cfg.rope_theta)
    return q, k, v


def gqa_apply(params, cfg: ModelConfig, x, *, positions=None, cache=None,
              cache_len=None):
    """Full forward (cache=None) or decode step / prefill-with-cache.

    x: (B, S, d).  When ``cache`` is given it is a dict {k, v} of
    (B, max_len, KV, hd); ``cache_len`` is the number of valid tokens already
    in it.  Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pos0 = jnp.asarray(0, jnp.int32) if cache_len is None else cache_len
    q_pos = pos0 + jnp.arange(S, dtype=jnp.int32)
    q, k, v = gqa_project(params, cfg, x, jnp.broadcast_to(q_pos, (B, S)),
                          positions=positions)

    scale = 1.0 / math.sqrt(hd)
    if cache is None:
        k_pos = jnp.arange(S, dtype=jnp.int32)
        out = attend(q, k, v, q_pos, k_pos, cfg.sliding_window, scale)
        new_cache = None
    elif S > 1:
        # prefill-from-empty: attend over the current keys directly, then
        # write (only) the last `max_len` positions into the ring buffer —
        # avoids duplicate scatter indices when S > window
        max_len = cache["k"].shape[1]
        W = min(S, max_len)
        out = attend(q, k, v, q_pos, q_pos, cfg.sliding_window, scale)
        idx = (pos0 + jnp.arange(S)[-W:]) % max_len
        ck = cache["k"].at[:, idx].set(k[:, -W:].astype(cache["k"].dtype))
        cv = cache["v"].at[:, idx].set(v[:, -W:].astype(cache["v"].dtype))
        k_pos_cache = cache["pos"].at[idx].set(q_pos[-W:])
        new_cache = {"k": ck, "v": cv, "pos": k_pos_cache}
    else:
        # single-token decode: the new k/v must NOT stay head-sharded (the
        # projection output is model-sharded) or GSPMD re-gathers the whole
        # cache to reconcile layouts — replicate the 1-token k/v instead
        from repro.dist.constraints import constrain_batch
        k = constrain_batch(k)
        v = constrain_batch(v)
        max_len = cache["k"].shape[1]
        idx = (pos0 + jnp.arange(S)) % max_len      # ring buffer for sliding windows
        ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
        k_pos_cache = cache["pos"].at[idx].set(q_pos)
        out = attend(q, ck, cv, q_pos, k_pos_cache, cfg.sliding_window, scale)
        new_cache = {"k": ck, "v": cv, "pos": k_pos_cache}
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bse,ed->bsd", out, params["w_o"]), new_cache


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.sliding_window:
        max_len = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((batch, max_len, KV, hd), dtype),
        "pos": jnp.full((max_len,), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


# ======================================================================== MLA

def mla_project(params, cfg: ModelConfig, x, q_pos):
    """Latent-form MLA projections.  q_pos: (B, S) absolute positions.

    Returns (q_full (B,S,H,lora+rope), c_kv (B,S,lora), k_rope (B,S,rope)) —
    q_nope already absorbed through W_UK into the latent space.  Shared by
    ``mla_apply`` and the paged-KV serving runner.
    """
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope_d, lora = m.qk_nope_head_dim, m.qk_rope_head_dim, m.kv_lora_rank

    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,re->bse", cq, params["w_uq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    c_kv = rmsnorm(params["kv_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]),
                   cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_kr"])        # shared, (B,S,rope_d)

    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], q_pos, cfg.rope_theta)[:, :, 0, :]

    # absorb q_nope into latent space: (B,S,H,lora)
    w_uk = params["w_uk"].reshape(lora, H, nope)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    q_full = jnp.concatenate([q_lat, q_rope], axis=-1)           # (B,S,H,lora+rope)
    return q_full, c_kv, k_rope


def mla_output(params, cfg: ModelConfig, out_lat):
    """Decompress attended latents (B,S,H,lora) through W_UV then W_O."""
    m = cfg.mla
    B, S, H = out_lat.shape[:3]
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", out_lat, w_uv).reshape(
        B, S, H * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", out, params["w_o"])


def mla_apply(params, cfg: ModelConfig, x, *, positions=None, cache=None,
              cache_len=None):
    """DeepSeek multi-head latent attention, latent (weight-absorbed) form.

    Scores are computed in the kv_lora latent space: the per-head nope query
    is projected through W_UK into the latent, concatenated with the shared
    rope key — so attention runs as MQA with head_dim = kv_lora + rope_dim
    and values = the latent itself (decompressed by W_UV afterwards).
    """
    m = cfg.mla
    B, S, d = x.shape
    nope, rope_d = m.qk_nope_head_dim, m.qk_rope_head_dim

    pos0 = jnp.asarray(0, jnp.int32) if cache_len is None else cache_len
    q_pos = pos0 + jnp.arange(S, dtype=jnp.int32)
    q_full, c_kv, k_rope = mla_project(params, cfg, x,
                                       jnp.broadcast_to(q_pos, (B, S)))

    if cache is None:
        kv_lat, kv_rope, k_pos = c_kv, k_rope, jnp.arange(S, dtype=jnp.int32)
        new_cache = None
    elif S > 1:
        # prefill-from-empty: attend over current latents, then store them
        idx = pos0 + jnp.arange(S)
        new_cache = {
            "c_kv": cache["c_kv"].at[:, idx].set(c_kv.astype(cache["c_kv"].dtype)),
            "k_rope": cache["k_rope"].at[:, idx].set(
                k_rope.astype(cache["k_rope"].dtype)),
            "pos": cache["pos"].at[idx].set(q_pos),
        }
        kv_lat, kv_rope, k_pos = c_kv, k_rope, q_pos
    else:
        idx = pos0 + jnp.arange(S)
        kv_lat = cache["c_kv"].at[:, idx].set(c_kv.astype(cache["c_kv"].dtype))
        kv_rope = cache["k_rope"].at[:, idx].set(k_rope.astype(cache["k_rope"].dtype))
        k_pos = cache["pos"].at[idx].set(q_pos)
        new_cache = {"c_kv": kv_lat, "k_rope": kv_rope, "pos": k_pos}

    k_full = jnp.concatenate([kv_lat, kv_rope], axis=-1)[:, :, None, :]  # MQA
    scale = 1.0 / math.sqrt(nope + rope_d)
    out_lat = attend(q_full, k_full, kv_lat[:, :, None, :], q_pos, k_pos,
                     0, scale)                                   # (B,S,H,lora)
    return mla_output(params, cfg, out_lat), new_cache


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((max_len,), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


# ============================================================ unified facade

def attention_init(key, cfg: ModelConfig, dtype=jnp.float32):
    return attn_init(key, cfg, dtype)


def attention_apply(params, cfg: ModelConfig, x, **kw):
    if cfg.attention == "mla":
        return mla_apply(params, cfg, x, **kw)
    return gqa_apply(params, cfg, x, **kw)


def attention_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    if cfg.attention == "mla":
        return mla_cache_init(cfg, batch, max_len, dtype)
    return gqa_cache_init(cfg, batch, max_len, dtype)
