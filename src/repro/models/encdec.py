"""Encoder-decoder backbone (SeamlessM4T language/decoder transformer).

Per the assignment, the modality frontend (mel-spectrogram + conformer
feature extractor) is a STUB: the encoder consumes precomputed frame
embeddings (B, F, d_model) supplied by ``input_specs``.  The decoder is a
standard causal transformer with cross-attention into the encoder output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, blocks
from repro.models.layers import (dense_init, embed_init, rmsnorm,
                                 rmsnorm_init, swiglu, swiglu_init)


# ----------------------------------------------------------------- encoder

def _enc_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(k1, cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "ffn": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _enc_attend(params, cfg: ModelConfig, h):
    """Bidirectional self-attention (no causal mask)."""
    B, S, d = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", h, params["w_q"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", h, params["w_k"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,de->bse", h, params["w_v"]).reshape(B, S, KV, hd)
    # bidirectional: give every query the max position so causal check passes
    q_pos = jnp.full((S,), S, jnp.int32)
    k_pos = jnp.arange(S, dtype=jnp.int32)
    out = attention.attend(q, k, v, q_pos, k_pos, 0, 1.0 / math.sqrt(hd))
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * hd), params["w_o"])


def _enc_layer_apply(params, cfg, h):
    h = h + _enc_attend(params["attn"], cfg, rmsnorm(params["norm1"], h, cfg.norm_eps))
    h = h + swiglu(params["ffn"], rmsnorm(params["norm2"], h, cfg.norm_eps))
    return h


# ------------------------------------------------------------------- model

def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ke, kd, kemb, khead, kdec = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    encoder = jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    decoder = jax.vmap(
        lambda k: blocks.block_init(k, cfg, "attn", "dense", cross=True,
                                    dtype=dtype))(dec_keys)
    return {
        "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "encoder": encoder,
        "decoder": decoder,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(khead, cfg.d_model, cfg.vocab_size, dtype),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, F, d_model) stubbed frontend embeddings."""
    def body(h, lp):
        return _enc_layer_apply(lp, cfg, h), None
    h, _ = jax.lax.scan(body, frames, params["encoder"])
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _dec_stack(params, cfg, h, enc_out, caches=None, cache_len=None):
    if caches is None:
        def body(hh, lp):
            hh, _, _ = blocks.block_apply(lp, cfg, "attn", "dense", hh,
                                          enc_out=enc_out)
            return hh, None
        h, _ = jax.lax.scan(body, h, params["decoder"])
        return h, None

    def body(hh, xs):
        lp, c = xs
        hh, nc, _ = blocks.block_apply(lp, cfg, "attn", "dense", hh, cache=c,
                                       cache_len=cache_len, enc_out=enc_out)
        return hh, nc
    h, new_caches = jax.lax.scan(body, h, (params["decoder"], caches))
    return h, new_caches


def forward(params, cfg: ModelConfig, tokens, extra_embeds=None, positions=None):
    """tokens: (B, S) decoder input; extra_embeds: (B, F, d) audio frames."""
    enc_out = encode(params, cfg, extra_embeds)
    h = params["embed"][tokens] * math.sqrt(cfg.d_model)
    h, _ = _dec_stack(params, cfg, h, enc_out)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"]), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    one = attention.attention_cache_init(cfg, batch, max_len, dtype)
    per_layer = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)
    # encoder output is computed at prefill and carried in the cache
    enc = jnp.zeros((batch, cfg.frontend_tokens or 1, cfg.d_model), dtype)
    return {"self": per_layer, "enc_out": enc}


def prefill(params, cfg: ModelConfig, caches, tokens, extra_embeds=None):
    """Encode the (stubbed) frames, fill decoder self-attn caches for the
    prompt, return last-position logits."""
    enc_out = encode(params, cfg, extra_embeds)
    h = params["embed"][tokens] * math.sqrt(cfg.d_model)
    h, new_self = _dec_stack(params, cfg, h, enc_out, caches["self"],
                             jnp.asarray(0, jnp.int32))
    h = rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"])[:, 0]
    return logits, {"self": new_self, "enc_out": enc_out.astype(caches["enc_out"].dtype)}


def decode_step(params, cfg: ModelConfig, caches, token, cache_len,
                positions=None):
    h = params["embed"][token[:, None]] * math.sqrt(cfg.d_model)
    h, new_self = _dec_stack(params, cfg, h, caches["enc_out"], caches["self"],
                             cache_len)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"])[:, 0]
    return logits, {"self": new_self, "enc_out": caches["enc_out"]}
