from repro.models.model import Model, build_model, cross_entropy
from repro.models.small import SmallModel

__all__ = ["Model", "SmallModel", "build_model", "cross_entropy"]
