"""Core layer primitives: norms, linear, SwiGLU, embeddings, RoPE / M-RoPE.

All parameters are plain dict pytrees of ``jnp.ndarray``; all apply functions
are pure.  Initializers take an explicit PRNG key.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init utils

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ------------------------------------------------------------------- RMSNorm

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# -------------------------------------------------------------------- SwiGLU

def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, theta: float = 10000.0, sections=(2, 3, 3)):
    """Qwen2-VL multimodal rotary embedding [arXiv:2409.12191].

    The rotary feature dim is split into three sections (temporal / height /
    width), each rotated by its own position-id stream.  ``positions_3d`` is
    (3, ..., S).  With text-only inputs all three streams coincide, matching
    vanilla RoPE behaviour.
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += s
        bounds.append(half * acc // total)
    freqs = rope_freqs(hd, theta)                         # (half,)
    # pick which position stream drives each frequency slot
    sec_id = jnp.zeros((half,), jnp.int32)
    prev = 0
    for i, b in enumerate(bounds):
        sec_id = jnp.where((jnp.arange(half) >= prev) & (jnp.arange(half) < b), i, sec_id)
        prev = b
    # gather per-slot positions: positions_3d (3, B, S) -> per-slot (B, S, half)
    p = positions_3d.astype(jnp.float32)                  # (3, B, S)
    p_slot = p[sec_id]                                    # (half, B, S) via fancy index on axis 0
    p_slot = jnp.moveaxis(p_slot, 0, -1)                  # (B, S, half)
    ang = p_slot[..., None, :] * freqs                    # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------- causal depthwise conv

def causal_conv1d_init(key, channels: int, kernel: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (kernel, channels)) / math.sqrt(kernel)).astype(dtype),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv1d(params, x):
    """Depthwise causal conv.  x: (B, S, C) -> (B, S, C)."""
    k = params["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * params["w"][i] for i in range(k))
    return out + params["b"]


def causal_conv1d_step(params, state, x_t):
    """Single decode step.  state: (B, k-1, C); x_t: (B, C)."""
    k = params["w"].shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)      # (B, k, C)
    out = jnp.einsum("bkc,kc->bc", window, params["w"]) + params["b"]
    return window[:, 1:, :], out
