"""Griffin recurrent block: RG-LRU (real-gated linear recurrent unit).
[arXiv:2402.19427]

    r_t = sigmoid(W_a x_t + b_a)             (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)             (input gate)
    log a_t = -c * softplus(Λ) * r_t          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is a diagonal linear scan — evaluated with
``jax.lax.associative_scan`` (XLA-native, O(log S) depth).  The Pallas TPU
kernel in ``repro.kernels.rglru`` computes the same scan chunk-sequentially
in VMEM.  The full Griffin block is: linear in -> temporal conv -> RG-LRU,
gated by a parallel GeLU branch, linear out.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (causal_conv1d, causal_conv1d_init,
                                 causal_conv1d_step, dense_init)

C_FACTOR = 8.0


def rglru_scan(a, bx):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + bx_t via associative scan.

    a, bx: (B, S, W) with a in (0, 1).  Returns h: (B, S, W).
    """
    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    ah, bh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return bh


def rglru_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    # Λ init so that a^c spans ~(0.9, 0.999) as in the paper
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / C_FACTOR))
    return {
        "w_x": dense_init(ks[0], d, w, dtype),          # recurrent branch in
        "w_gate": dense_init(ks[1], d, w, dtype),       # gelu gate branch
        "conv": causal_conv1d_init(ks[2], w, 4, dtype),
        "w_a": dense_init(ks[3], w, w, dtype, scale=0.1),
        "b_a": jnp.zeros((w,), dtype),
        "w_i": dense_init(ks[4], w, w, dtype, scale=0.1),
        "b_i": jnp.zeros((w,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _gates(params, xw):
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xw, params["w_a"]) + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xw, params["w_i"]) + params["b_i"])
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta, i.astype(jnp.float32)


def rglru_apply(params, cfg: ModelConfig, x, *, cache=None, cache_len=None,
                positions=None):
    """x: (B,S,d). cache: {"conv": (B,3,W), "h": (B,W)}."""
    B, S, d = x.shape
    xw = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"]))

    if cache is None or S > 1:
        # full scan (training, or prefill-from-empty when a cache is given)
        xc = causal_conv1d(params["conv"], xw)
        a, beta, i = _gates(params, xc)
        bx = beta * i * xc.astype(jnp.float32)
        h = rglru_scan(a, bx)
        new_cache = None
        if cache is not None:
            k = params["conv"]["w"].shape[0] - 1
            new_cache = {"conv": xw[:, -k:], "h": h[:, -1]}
    else:
        conv_state, h_prev = cache["conv"], cache["h"]
        conv_state, xc1 = causal_conv1d_step(params["conv"], conv_state, xw[:, 0])
        a, beta, i = _gates(params, xc1)
        h1 = a * h_prev + beta * i * xc1.astype(jnp.float32)
        h = h1[:, None, :]
        new_cache = {"conv": conv_state, "h": h1}

    out = h.astype(x.dtype) * gate
    return jnp.einsum("bsw,wd->bsd", out, params["w_out"]), new_cache


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
