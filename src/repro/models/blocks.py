"""Unified residual block: mixer (attn | rglru | ssm) + FFN (dense | moe | none).

A block optionally carries a cross-attention sublayer (encoder-decoder
architectures).  Params and caches are plain pytrees so stacks of blocks can
be scanned with ``jax.lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, moe, rglru, ssm
from repro.models.layers import rmsnorm, rmsnorm_init, swiglu, swiglu_init


def ffn_kind(cfg: ModelConfig, layer_idx: int) -> str:
    if cfg.ssm is not None and cfg.pattern[layer_idx] == "ssm" and cfg.d_ff == 0:
        return "none"
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        return "moe"
    if cfg.d_ff == 0:
        return "none"
    return "dense"


def block_init(key, cfg: ModelConfig, kind: str, ffn: str, *, cross: bool = False,
               dtype=jnp.float32):
    kmix, kffn, kcross = jax.random.split(key, 3)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "attn":
        p["mixer"] = attention.attention_init(kmix, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru.rglru_init(kmix, cfg, dtype)
    elif kind == "ssm":
        p["mixer"] = ssm.mamba2_init(kmix, cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["cross_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attention.attn_init(kcross, cfg, dtype)
    if ffn == "dense":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = swiglu_init(kffn, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = moe.moe_init(kffn, cfg, dtype)
    return p


def _mixer_apply(params, cfg, kind, h, **kw):
    if kind == "attn":
        return attention.attention_apply(params, cfg, h, **kw)
    if kind == "rglru":
        return rglru.rglru_apply(params, cfg, h, **kw)
    if kind == "ssm":
        return ssm.mamba2_apply(params, cfg, h, **kw)
    raise ValueError(kind)


def cross_attend(params, cfg: ModelConfig, h, enc_out):
    """Cross-attention sublayer (queries from h, keys/values from enc_out)."""
    import math
    B, S, d = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    Se = enc_out.shape[1]
    q = jnp.einsum("bsd,de->bse", h, params["w_q"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", enc_out, params["w_k"]).reshape(B, Se, KV, hd)
    v = jnp.einsum("bsd,de->bse", enc_out, params["w_v"]).reshape(B, Se, KV, hd)
    # bidirectional: all encoder positions visible
    q_pos = jnp.full((S,), Se, jnp.int32)
    k_pos = jnp.arange(Se, dtype=jnp.int32)
    out = attention.attend(q, k, v, q_pos, k_pos, 0, 1.0 / math.sqrt(hd))
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * hd), params["w_o"])


def block_apply(params, cfg: ModelConfig, kind: str, ffn: str, h, *,
                cache=None, cache_len=None, positions=None, enc_out=None):
    """Returns (h, new_cache, aux_loss)."""
    from repro.dist.constraints import constrain_batch
    h = constrain_batch(h)
    mixed, new_cache = _mixer_apply(params["mixer"], cfg, kind,
                                    rmsnorm(params["norm1"], h, cfg.norm_eps),
                                    cache=cache, cache_len=cache_len,
                                    positions=positions)
    h = h + mixed
    if "cross" in params and enc_out is not None:
        h = h + cross_attend(params["cross"], cfg,
                             rmsnorm(params["cross_norm"], h, cfg.norm_eps),
                             enc_out)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        h = h + swiglu(params["ffn"], rmsnorm(params["norm2"], h, cfg.norm_eps))
    elif ffn == "moe":
        out, aux = moe.moe_apply(params["ffn"], cfg,
                                 rmsnorm(params["norm2"], h, cfg.norm_eps))
        h = h + out
    return constrain_batch(h), new_cache, aux


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.float32):
    if kind == "attn":
        return attention.attention_cache_init(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return rglru.rglru_cache_init(cfg, batch, dtype)
    if kind == "ssm":
        return ssm.mamba2_cache_init(cfg, batch, dtype)
    raise ValueError(kind)
