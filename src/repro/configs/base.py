"""Model configuration dataclass spanning all assigned architecture families.

Every assigned architecture (dense / moe / ssm / hybrid / audio / vlm) is
expressed as a ``ModelConfig``.  Reduced variants (for CPU smoke tests) are
derived with :meth:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dimensions."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (DeepSeek-style)."""

    n_routed_experts: int
    n_shared_experts: int
    top_k: int
    d_ff_expert: int
    # Layers [0, first_k_dense) use a dense FFN instead of MoE (DeepSeek uses 1-3).
    first_k_dense: int = 1
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD configuration."""

    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # --- attention ---
    attention: str = "full"            # full | sliding | mla | none
    sliding_window: int = 0            # used when attention == "sliding"
    qkv_bias: bool = False
    rope: str = "rope"                 # rope | mrope | none
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    # --- state space ---
    ssm: Optional[SSMConfig] = None
    # --- hybrid block pattern, cycled over layers (e.g. RecurrentGemma) ---
    block_pattern: Tuple[str, ...] = ()   # entries: "attn" | "rglru" | "ssm"
    rglru_width: int = 0                  # 0 -> d_model
    # --- encoder-decoder (audio) ---
    n_encoder_layers: int = 0
    # --- multi-token prediction (DeepSeek-V3) ---
    mtp_depth: int = 0
    # --- modality frontend stub ---
    frontend: Optional[str] = None     # None | "audio" | "vision"
    frontend_tokens: int = 0           # embeddings provided per example by the stub
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    citation: str = ""

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds, cycling ``block_pattern``."""
        if self.arch_type == "ssm":
            base: Tuple[str, ...] = ("ssm",)
        elif self.block_pattern:
            base = self.block_pattern
        else:
            base = ("attn",)
        return tuple(base[i % len(base)] for i in range(self.n_layers))

    @property
    def supports_long_context(self) -> bool:
        """True when decode with a 500k context is sub-quadratic by design."""
        kinds = set(self.pattern)
        if kinds <= {"ssm", "rglru"}:
            return True
        if "attn" in kinds and self.attention == "sliding":
            return True
        if self.block_pattern and "attn" in kinds:
            # hybrid local-attention blocks use a bounded window
            return self.sliding_window > 0
        return False

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        per_kind = {}
        hd = self.resolved_head_dim
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        per_kind["attn"] = attn + 3 * d * self.d_ff  # swiglu
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            per_kind["ssm"] = d * (2 * di + 2 * self.ssm.d_state + self.ssm.n_heads(d)) + di * d
        if self.rglru_width or "rglru" in self.pattern:
            w = self.rglru_width or d
            per_kind["rglru"] = d * w * 2 + 3 * w * w // 1 + w * d + 3 * d * self.d_ff
        counts = {}
        for k in self.pattern:
            counts[k] = counts.get(k, 0) + 1
        for k, c in counts.items():
            total += c * per_kind.get(k, per_kind.get("attn", 0))
        if self.moe is not None:
            # replace dense FFN with expert FFNs on MoE layers
            moe_layers = max(0, L - self.moe.first_k_dense)
            total -= moe_layers * 3 * d * self.d_ff
            total += moe_layers * (
                (self.moe.n_routed_experts + self.moe.n_shared_experts)
                * 3 * d * self.moe.d_ff_expert
                + d * self.moe.n_routed_experts)
            total += self.moe.first_k_dense * 0  # dense layers already counted
        total += self.n_encoder_layers * per_kind.get("attn", 0)
        return int(total)

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE activates top_k + shared only)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        total = self.n_params()
        moe_layers = max(0, L - self.moe.first_k_dense)
        inactive = (self.moe.n_routed_experts - self.moe.top_k)
        total -= moe_layers * inactive * 3 * d * self.moe.d_ff_expert
        return int(total)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (2 layers, d<=512)."""
        kw = dict(
            n_layers=2,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(1, self.n_heads))),
            head_dim=64,
            d_ff=512 if self.d_ff else 0,   # attn-free families stay FFN-less
            vocab_size=512,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_encoder_layers=2 if self.is_encdec else 0,
            frontend_tokens=8 if self.frontend else 0,
            mtp_depth=min(self.mtp_depth, 1),
            name=self.name + "-reduced",
        )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_routed_experts=4,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                top_k=2, d_ff_expert=128, first_k_dense=1,
                # dropless for smoke tests: capacity == tokens-per-group, so
                # step-by-step decode matches full prefill exactly
                capacity_factor=4 / 2)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32,
                                            chunk_size=16)
        if self.rglru_width:
            kw["rglru_width"] = 256
        if self.block_pattern:
            kw["n_layers"] = max(2, len(self.block_pattern))
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
