"""Paper-scale model configs for the faithful TL reproduction (Table 1/2).

The paper trains ResNet-18 / LeNet-5 / ConvNet / DatRet (MLP) / a small
Transformer.  The TL protocol is model-agnostic; our faithful reproduction
exercises it with the three model families the paper uses (MLP, CNN,
Transformer) at CPU-tractable sizes via ``repro.models.small``:

* ``datret``      — the DatRet fully-connected net (512-256-...-4, ELU) used
                    for MIMIC-IV and BANK [paper §4.1.2].
* ``convnet``     — a small ConvNet in the spirit of LeNet-5/ConvNet for the
                    image datasets.
* ``tiny_transformer`` — the paper's IMDB sentiment Transformer, reduced.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class SmallModelConfig:
    name: str
    family: str                       # mlp | conv | transformer
    in_shape: Tuple[int, ...]         # per-example input shape
    n_classes: int
    hidden: Tuple[int, ...] = ()      # mlp widths
    conv_channels: Tuple[int, ...] = ()
    d_model: int = 0
    n_heads: int = 0
    n_layers: int = 0
    vocab_size: int = 0
    seq_len: int = 0


DATRET = SmallModelConfig(
    name="datret", family="mlp", in_shape=(32,), n_classes=2,
    hidden=(512, 256, 128, 64, 32, 16, 8, 4))

CONVNET = SmallModelConfig(
    name="convnet", family="conv", in_shape=(16, 16, 1), n_classes=10,
    conv_channels=(16, 32), hidden=(128,))

TINY_TRANSFORMER = SmallModelConfig(
    name="tiny_transformer", family="transformer", in_shape=(32,), n_classes=2,
    d_model=64, n_heads=4, n_layers=2, vocab_size=256, seq_len=32)

SMALL_MODELS = {m.name: m for m in (DATRET, CONVNET, TINY_TRANSFORMER)}
