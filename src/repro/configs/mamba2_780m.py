"""Mamba2-780M — attention-free SSM with SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,              # d_inner / head_dim = 3072/64
    n_kv_heads=48,
    d_ff=0,                  # attn-free, no FFN blocks (Mamba-2 uses pure SSD stacks)
    vocab_size=50280,
    attention="none",
    rope="none",
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_kernel=4, chunk_size=256),
    citation="arXiv:2405.21060",
)
