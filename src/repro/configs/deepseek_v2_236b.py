"""DeepSeek-V2 236B — MoE, MLA kv_lora=512, 2 shared + 160 routed top-6. [arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,              # dense FFN on the first layer [arXiv:2405.04434]
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed_experts=160, n_shared_experts=2, top_k=6,
                  d_ff_expert=1536, first_k_dense=1),
    rope="rope",
    citation="arXiv:2405.04434",
)
