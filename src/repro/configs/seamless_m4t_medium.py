"""SeamlessM4T-medium — encoder-decoder multimodal backbone. [arXiv:2308.11596]

The speech frontend (mel filterbank + conformer feature extractor) is a STUB
per assignment: ``input_specs`` provides precomputed frame embeddings of shape
(batch, frames, d_model).  We implement the transformer encoder + decoder
(cross-attention) that consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,              # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    attention="full",
    rope="none",              # learned/sinusoidal positions in the original; stubbed as none
    frontend="audio",
    frontend_tokens=1024,     # precomputed speech frames per example
    citation="arXiv:2308.11596",
)
