"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention, 2:1. [arXiv:2402.19427]

Block pattern (Griffin): two recurrent (RG-LRU) residual blocks followed by
one local-attention block, cycled.  Local attention is MQA (kv=1) with a
2048-token window, so 500k-context decode is O(window + state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention="sliding",
    sliding_window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    rglru_width=4096,
    rope="rope",
    citation="arXiv:2402.19427",
)
