"""Architecture/config registry.

``get_config(arch_id)`` returns the exact assigned configuration;
``get_config(arch_id, reduced=True)`` the CPU-smoke variant.
"""
from repro.configs.base import InputShape, MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.configs.shapes import SHAPES, get_shape

from repro.configs import (
    deepseek_v3_671b,
    deepseek_v2_236b,
    qwen2_5_32b,
    stablelm_12b,
    starcoder2_3b,
    recurrentgemma_9b,
    seamless_m4t_medium,
    qwen2_vl_72b,
    deepseek_7b,
    mamba2_780m,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_v3_671b,
        deepseek_v2_236b,
        qwen2_5_32b,
        stablelm_12b,
        starcoder2_3b,
        recurrentgemma_9b,
        seamless_m4t_medium,
        qwen2_vl_72b,
        deepseek_7b,
        mamba2_780m,
    )
}


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    cfg = ARCHS[arch_id]
    return cfg.reduced() if reduced else cfg


def list_archs():
    return sorted(ARCHS)


__all__ = [
    "ARCHS", "SHAPES", "InputShape", "MLAConfig", "ModelConfig", "MoEConfig",
    "SSMConfig", "get_config", "get_shape", "list_archs",
]
