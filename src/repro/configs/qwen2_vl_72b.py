"""Qwen2-VL-72B — VLM language backbone, M-RoPE, dynamic resolution. [arXiv:2409.12191]

The vision tower (ViT + projector) is a STUB per assignment: ``input_specs``
provides precomputed patch embeddings (batch, n_patches, d_model) that are
prepended to the token stream.  M-RoPE splits the rotary dims into three
sections (temporal / height / width position ids).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    attention="full",
    qkv_bias=True,
    rope="mrope",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_tokens=256,      # stubbed image patches per example
    citation="arXiv:2409.12191",
)
