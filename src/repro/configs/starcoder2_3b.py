"""StarCoder2-3B — dense GQA (kv=2), RoPE, sliding-window 4096. [arXiv:2402.19173]

StarCoder2 trains with sliding-window attention (window 4096), which makes
``long_500k`` decode O(window) per token — this arch runs the long-context
shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    attention="sliding",
    sliding_window=4096,
    qkv_bias=True,
    rope="rope",
    citation="arXiv:2402.19173",
)
