"""DeepSeek-V3 671B — MoE, MLA, 1 shared + 256 routed top-8, MTP. [arXiv:2412.19437]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: all heads read the shared latent KV
    head_dim=128,
    d_ff=18432,              # dense FFN on the first_k_dense layers [arXiv:2412.19437 tab.1]
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed_experts=256, n_shared_experts=1, top_k=8,
                  d_ff_expert=2048, first_k_dense=3),
    mtp_depth=1,             # multi-token prediction, depth 1
    rope="rope",
    citation="arXiv:2412.19437",
)
