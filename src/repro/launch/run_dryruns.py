"""Driver: run the full (arch × shape × mesh) dry-run grid, one subprocess
per combination (the dry-run forces 512 host devices, which must not leak
into this process), collecting JSON artifacts.

    PYTHONPATH=src python -m repro.launch.run_dryruns [--mesh single multi]
        [--archs a b c] [--shapes s1 s2] [--out experiments/artifacts]
        [--timeout 900] [--skip-existing]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import SHAPES, list_archs


def run_one(arch, shape, mesh, out, remat, tag, timeout, extra=()):
    name = f"{arch}__{shape}__{mesh}__{tag}.json"
    path = os.path.join(out, name)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out, "--remat", remat,
           "--tag", tag] + list(extra)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout)
        ok = proc.returncode == 0
        err = proc.stderr[-2000:] if not ok else ""
    except subprocess.TimeoutExpired:
        ok, err = False, f"timeout after {timeout}s"
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "timeout", "timeout_s": timeout}, f)
    dt = time.time() - t0
    status = "?"
    if os.path.exists(path):
        with open(path) as f:
            status = json.load(f).get("status", "?")
    print(f"[{dt:6.1f}s] {arch:22s} {shape:12s} {mesh:7s} -> {status}"
          + (f"  {err.splitlines()[-1] if err else ''}" if not ok else ""),
          flush=True)
    return status


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=list_archs())
    ap.add_argument("--shapes", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", nargs="*", default=["single", "multi"])
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--remat", default="tl")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--timeout", type=int, default=900)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-winning flags per shape kind")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    results = {}
    for mesh in args.mesh:
        for arch in args.archs:
            for shape in args.shapes:
                key = f"{arch}__{shape}__{mesh}"
                path = os.path.join(args.out, key + f"__{args.tag}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        st = json.load(f).get("status")
                    if st in ("ok", "skipped"):
                        results[key] = st
                        print(f"[cached ] {key} -> {st}", flush=True)
                        continue
                extra = []
                if args.optimized:
                    extra = ["--act-constraints"]
                    if "decode" in shape or "500k" in shape:
                        extra += ["--no-serve-fsdp", "--cache-seq-shard"]
                results[key] = run_one(arch, shape, mesh, args.out,
                                       args.remat, args.tag, args.timeout,
                                       extra)
    n_ok = sum(1 for v in results.values() if v == "ok")
    n_skip = sum(1 for v in results.values() if v == "skipped")
    n_bad = len(results) - n_ok - n_skip
    print(f"\nTOTAL: {n_ok} ok, {n_skip} designed-skips, {n_bad} failures "
          f"of {len(results)}")
    return 0 if n_bad == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
