"""Serving CLI: static-batch oracle + the continuous-batching engine.

Static (the oracle the engine is tested against):
    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Continuous batching over the paged KV cache (``repro.serve``):
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --reduced --engine continuous --attention paged --requests 8 --gen 16

Serve-under-fire drills (the CI ``serve-chaos`` job runs both):

    # supervised chaos: inject a decode hang + crash; the engine rebuilds
    # from host truth and the run must stay token-identical to the oracle
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --reduced --engine continuous --requests 4 --gen 8 \
        --chaos hang:3,crash:6 --watchdog-s 30
    # -> prints "SERVE_DRILL token_identical=true ...", exit 0
    # -> exit 3 when any completed stream diverges from the oracle

    # unsupervised: the same fault must fail LOUDLY (exit 2), never wedge
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --reduced --engine continuous --requests 4 --gen 8 \
        --chaos hang:1 --watchdog-s 30 --no-supervise   # -> exit 2
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.tl_step import make_serve_step
from repro.models import build_model

# one compiled serve step per config — generate() must never re-jit per call
_STEP_CACHE: dict = {}


def _serve_step_fn(model, cfg):
    fn = _STEP_CACHE.get(cfg.name)
    if fn is None:
        fn = jax.jit(make_serve_step(model, cfg))
        _STEP_CACHE[cfg.name] = fn
    return fn


def generate(model, cfg, params, prompts, gen_len: int, *, temperature=0.0,
             key=None, seeds=None):
    """prompts: (B, P) int32.  Greedy (or sampled) continuation, (B, gen_len).

    Sampling uses per-row RNG streams from ``repro.serve.sampling`` — row b
    draws from ``fold_in(fold_in(key, seeds[b]), step)`` — so a request's
    stream depends only on (key, seed, step), exactly matching the
    continuous engine's streams.  ``key=None`` defaults to ``PRNGKey(0)``;
    ``seeds`` defaults to ``arange(B)``.
    """
    from repro.serve.sampling import request_key, sample_tokens
    B, P = prompts.shape
    max_len = P + gen_len
    cache = model.init_cache(B, max_len)
    if cfg.is_encdec:
        frames = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model))
        logits, cache = model.prefill(params, cache, prompts, frames)
    else:
        logits, cache = model.prefill(params, cache, prompts)
    step_fn = _serve_step_fn(model, cfg)

    if temperature > 0:
        base = jax.random.PRNGKey(0) if key is None else key
        seeds = jnp.arange(B) if seeds is None else jnp.asarray(seeds)
        keys = jax.vmap(lambda s: request_key(base, s))(seeds)
    else:
        keys = jnp.zeros((B, 2), jnp.uint32)
    temps = jnp.full((B,), temperature, jnp.float32)

    out = []
    tok = sample_tokens(logits, keys, jnp.zeros((B,), jnp.int32), temps)
    for t in range(gen_len):
        out.append(tok)
        if t == gen_len - 1:
            break
        logits, cache = step_fn(params, cache, tok,
                                jnp.asarray(P + t, jnp.int32))
        tok = sample_tokens(logits, keys, jnp.full((B,), t + 1, jnp.int32),
                            temps)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--engine", choices=["static", "continuous"],
                    default="static")
    ap.add_argument("--attention", choices=["paged", "dense"],
                    default="paged", help="continuous-engine decode path")
    ap.add_argument("--batch", "--requests", dest="batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--decode-priority", type=int, default=1)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO: absolute deadline = submit time "
                         "+ this many ms; past it requests are shed/aborted")
    ap.add_argument("--chaos", default=None,
                    help="scripted decode faults, e.g. hang:3,crash:6 "
                         "(see repro.serve.faults.parse_chaos)")
    ap.add_argument("--watchdog-s", type=float, default=30.0,
                    help="decode-step watchdog deadline (hang detection)")
    ap.add_argument("--no-supervise", action="store_true",
                    help="disable fault supervision: an injected fault "
                         "fails loudly (exit 2) instead of recovering")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    if args.engine == "static":
        t0 = time.time()
        tokens = generate(model, cfg, params, prompts, args.gen,
                          temperature=args.temperature, key=key)
        dt = time.time() - t0
        print(f"generated {tokens.shape} in {dt:.2f}s "
              f"({args.batch*args.gen/dt:.1f} tok/s)")
        print(np.asarray(tokens[:2]))
        return tokens

    from repro.serve import (Request, ServeEngine, ServeFault,
                             ServeFaultSpec, parse_chaos)
    faults = None
    if args.chaos:
        faults = ServeFaultSpec(seed=args.seed,
                                drills=parse_chaos(args.chaos))
    eng = ServeEngine(model, cfg, params, num_pages=args.num_pages,
                      page_size=args.page_size, max_slots=args.max_slots,
                      max_len=args.prompt_len + args.gen,
                      attention=args.attention,
                      decode_priority=args.decode_priority, seed=args.seed,
                      faults=faults, watchdog_s=args.watchdog_s,
                      supervise=not args.no_supervise)
    t0 = time.time()
    for r in range(args.batch):
        now = time.time()
        deadline = (None if args.deadline_ms is None
                    else now + args.deadline_ms / 1e3)
        eng.submit(Request(rid=r, prompt=np.asarray(prompts[r]),
                           max_new_tokens=args.gen,
                           temperature=args.temperature, seed=r,
                           arrival=now, deadline=deadline))
    try:
        results = eng.run()
    except ServeFault as e:
        print(f"FATAL: unsupervised serving fault\n{e}", file=sys.stderr)
        raise SystemExit(2)
    dt = time.time() - t0
    stats = eng.stats()
    n_tok = sum(len(r.tokens) for r in results.values())
    print(f"served {args.batch} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, engine={args.engine}, "
          f"attention={args.attention})")
    print(f"  shed={stats['n_shed']} deadline_aborts="
          f"{stats['n_deadline_aborts']} preempted={stats['n_preempted']} "
          f"restored={stats['n_restored']} rebuilds={stats['n_rebuilds']}"
          + (f" shed_rids={stats['shed_rids']}" if stats['shed_rids']
             else ""))
    for rep in eng.recoveries:
        d = rep.as_dict()
        print(f"  recovery step={d['step']} cause={d['cause']} "
              f"survivors={d['n_survivors']} detect={d['detect_s']}s "
              f"rebuild={d['rebuild_s']}s reprefill={d['reprefill_s']}s "
              f"first_token={d['first_token_s']}s")
    for r in sorted(results.values(), key=lambda r: r.rid)[:2]:
        print(f"  rid={r.rid} [{r.finish_reason}] {r.tokens}")

    if args.chaos:
        # drill verification: every stream the engine completed (and every
        # partial prefix) must be bit-identical to the fault-free oracle
        oracle = np.asarray(generate(
            model, cfg, params, prompts, args.gen,
            temperature=args.temperature, key=key,
            seeds=list(range(args.batch))))
        identical = True
        for r in results.values():
            want = oracle[r.rid][:len(r.tokens)].tolist()
            full = (r.finish_reason == "length"
                    and len(r.tokens) == args.gen)
            if r.tokens != want or (r.finish_reason == "length"
                                    and not full):
                identical = False
                print(f"  DIVERGED rid={r.rid}: engine={r.tokens} "
                      f"oracle={want}", file=sys.stderr)
        print(f"SERVE_DRILL token_identical={str(identical).lower()} "
              f"rebuilds={stats['n_rebuilds']} shed={stats['n_shed']} "
              f"completed={sum(1 for r in results.values() if r.finish_reason in ('eos', 'length'))}"
              f"/{args.batch}")
        if not identical:
            raise SystemExit(3)
    return results


if __name__ == "__main__":
    main()
