"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.tl_step import make_serve_step
from repro.models import build_model


def generate(model, cfg, params, prompts, gen_len: int, *, temperature=0.0,
             key=None):
    """prompts: (B, P) int32.  Greedy (or sampled) continuation."""
    B, P = prompts.shape
    max_len = P + gen_len
    cache = model.init_cache(B, max_len)
    if cfg.is_encdec:
        frames = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model))
        logits, cache = model.prefill(params, cache, prompts, frames)
    else:
        logits, cache = model.prefill(params, cache, prompts)
    step_fn = jax.jit(make_serve_step(model, cfg))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(gen_len):
        out.append(tok)
        logits, cache = step_fn(params, cache, tok,
                                jnp.asarray(P + t, jnp.int32))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    tokens = generate(model, cfg, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print(np.asarray(tokens[:2]))
    return tokens


if __name__ == "__main__":
    main()
