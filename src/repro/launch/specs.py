"""ShapeDtypeStruct stand-ins for every model input — nothing is allocated.

``input_specs(cfg, shape)`` returns the abstract inputs for the step the
shape's kind lowers:
  train   -> {"tokens", "targets"[, "embeds"]}
  prefill -> (cache, tokens[, embeds])   with empty caches of max_len=seq
  decode  -> (cache, token, cache_len)   with full caches of max_len=seq

Frontend stubs (assignment carve-out): [vlm]/[audio] shapes include an
"embeds" ShapeDtypeStruct of precomputed patch/frame embeddings; text token
length shrinks so the total sequence stays the assigned seq_len.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import Model

SDS = jax.ShapeDtypeStruct


def abstract_params(model: Model, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), dtype))


def abstract_cache(model: Model, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len, dtype))


def text_len(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.frontend and not cfg.is_encdec:
        return shape.seq_len - cfg.frontend_tokens
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, SDS]:
    B = shape.global_batch
    S = text_len(cfg, shape)
    if shape.kind == "train":
        specs = {"tokens": SDS((B, S), jnp.int32),
                 "targets": SDS((B, S), jnp.int32)}
        if cfg.frontend:
            specs["embeds"] = SDS((B, cfg.frontend_tokens, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.frontend:
            specs["embeds"] = SDS((B, cfg.frontend_tokens, cfg.d_model), dtype)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"token": SDS((B,), jnp.int32),
            "cache_len": SDS((), jnp.int32)}
