"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run process forces
512 host devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types when this jax supports them
    (>= 0.5); plain mesh construction otherwise."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return make_mesh_compat((n_data, n_model), ("data", "model"))


def make_host_mesh(n_model: int = None):
    """(data, model) mesh over every visible device.  ``n_model`` defaults to
    2 when the device count is even (so TP paths are exercised), else 1.
    With ``--xla_force_host_platform_device_count=8`` this is the forced-8
    CPU mesh the engine equivalence tests run on."""
    n = jax.device_count()
    if n_model is None:
        n_model = 2 if n % 2 == 0 and n >= 2 else 1
    return make_mesh_compat((n // n_model, n_model), ("data", "model"))


def make_multipod_debug_mesh(pod: int = 2, data: int = 2, model: int = 2):
    """Smallest mesh carrying the full multi-pod axis set (pod, data, model);
    runnable on 8 forced host devices.  Exercises the composite (pod, data)
    batch axes of :func:`repro.dist.sharding.batch_axes` without 512 chips."""
    return make_mesh_compat((pod, data, model), ("pod", "data", "model"))


def resolve_mesh(kind: str, *, multi_pod: bool = False):
    """CLI-facing mesh selection for the training engine.

    * ``debug``      — the largest of (2,2) / (2,1) / (1,1) the host's device
      count supports.  On a plain single-device CPU this degenerates to a
      (1,1) mesh: the same jit path, shardings and donation as at scale,
      with every collective a no-op.
    * ``host``       — all visible devices as (data, model); combined with a
      forced ``--xla_force_host_platform_device_count`` this is the CPU
      stand-in for a real slice.
    * ``production`` — the 16x16 pod mesh (``multi_pod=True``: 2x16x16 with
      the (pod, data, model) axes); lower/compile-only on a laptop, the real
      thing on the actual slice.
    """
    if kind == "debug":
        n = jax.device_count()
        if n >= 4:
            return make_debug_mesh(2, 2)
        if n >= 2:
            return make_debug_mesh(2, 1)
        return make_debug_mesh(1, 1)
    if kind == "host":
        return make_host_mesh()
    if kind == "production":
        return make_production_mesh(multi_pod=multi_pod)
    raise ValueError(f"unknown mesh kind: {kind!r}")
