"""Production mesh construction + elastic reshrink planning.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run process forces
512 host devices while tests/benches must see 1.

:func:`plan_reshrink` is the elastic engine's mesh half: given a mesh and a
set of lost device ids it re-factorizes the ``(pod, data, model)`` shape
over the survivors — degrading the **data** axis first (pod second, model
only as a last resort: a model-axis change re-lays-out every weight and
grows per-chip parameter memory) — and validates the result against
``repro.dist.sharding.param_specs`` divisibility before the engine commits
to re-sharding onto it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Tuple

import jax
import numpy as np


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types when this jax supports them
    (>= 0.5); plain mesh construction otherwise."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return make_mesh_compat((n_data, n_model), ("data", "model"))


def make_host_mesh(n_model: int = None):
    """(data, model) mesh over every visible device.  ``n_model`` defaults to
    2 when the device count is even (so TP paths are exercised), else 1.
    With ``--xla_force_host_platform_device_count=8`` this is the forced-8
    CPU mesh the engine equivalence tests run on."""
    n = jax.device_count()
    if n_model is None:
        n_model = 2 if n % 2 == 0 and n >= 2 else 1
    return make_mesh_compat((n // n_model, n_model), ("data", "model"))


def make_multipod_debug_mesh(pod: int = 2, data: int = 2, model: int = 2):
    """Smallest mesh carrying the full multi-pod axis set (pod, data, model);
    runnable on 8 forced host devices.  Exercises the composite (pod, data)
    batch axes of :func:`repro.dist.sharding.batch_axes` without 512 chips."""
    return make_mesh_compat((pod, data, model), ("pod", "data", "model"))


def resolve_mesh(kind: str, *, multi_pod: bool = False):
    """CLI-facing mesh selection for the training engine.

    * ``debug``      — the largest of (2,2) / (2,1) / (1,1) the host's device
      count supports.  On a plain single-device CPU this degenerates to a
      (1,1) mesh: the same jit path, shardings and donation as at scale,
      with every collective a no-op.
    * ``host``       — all visible devices as (data, model); combined with a
      forced ``--xla_force_host_platform_device_count`` this is the CPU
      stand-in for a real slice.
    * ``production`` — the 16x16 pod mesh (``multi_pod=True``: 2x16x16 with
      the (pod, data, model) axes); lower/compile-only on a laptop, the real
      thing on the actual slice.
    """
    if kind == "debug":
        n = jax.device_count()
        if n >= 4:
            return make_debug_mesh(2, 2)
        if n >= 2:
            return make_debug_mesh(2, 1)
        return make_debug_mesh(1, 1)
    if kind == "host":
        return make_host_mesh()
    if kind == "production":
        return make_production_mesh(multi_pod=multi_pod)
    raise ValueError(f"unknown mesh kind: {kind!r}")


# --------------------------------------------------------- elastic reshrink

class ReshrinkError(RuntimeError):
    """No valid mesh factorization exists over the surviving devices."""


@dataclass(frozen=True)
class ReshrinkPlan:
    """Outcome of :func:`plan_reshrink`: the new mesh plus the audit trail
    the engine's recovery report carries."""

    mesh: object                       # jax.sharding.Mesh over the survivors
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    lost_ids: Tuple[int, ...]
    n_idle: int                        # survivors the new shape leaves unused
    degraded_axes: Tuple[str, ...]     # axes that shrank, major-to-minor


def validate_param_divisibility(params, cfg, mesh) -> None:
    """Assert every ``param_specs`` spec materializes on ``mesh``: each
    spec entry's mesh-axis product must divide its dim exactly.
    ``param_specs`` filters non-dividing axes by construction, so a failure
    here means the sharding layer's contract broke — the reshrink must not
    commit to the mesh."""
    from repro.dist.sharding import _mesh_sizes, param_pspec, spec_divisible
    sizes = _mesh_sizes(mesh)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        spec = param_pspec(path, leaf, cfg, axis_sizes=sizes)
        if not spec_divisible(leaf.shape, spec, sizes):
            raise ReshrinkError(
                f"param {jax.tree_util.keystr(path)} shape {tuple(leaf.shape)} "
                f"does not divide over spec {spec} on the reshrunk mesh "
                f"{sizes} — refusing to re-shard")


def plan_reshrink(mesh, lost_device_ids: Iterable[int], *, global_batch: int,
                  params=None, cfg=None) -> ReshrinkPlan:
    """Re-factorize ``(pod, data, model)`` over the surviving devices.

    Degradation order (the cheapest semantic change first):

    1. **data** — shrinking data-parallel width only re-slices the batch;
       the candidate must keep ``global_batch`` divisible by the composite
       (pod, data) width so the batch stays sharded (``tokens_pspec``'s own
       criterion);
    2. **pod** — collapses cross-pod replication into the remaining pods;
    3. **model** — last resort: every weight re-lays-out and per-chip
       parameter memory grows.

    The survivors keep their original mesh-major order (a deterministic
    function of the lost set), so two processes that observe the same loss
    derive the same mesh.  When ``params``/``cfg`` are given the winning
    shape is validated against ``param_specs`` divisibility before being
    returned.
    """
    lost = frozenset(int(i) for i in lost_device_ids)
    survivors = [d for d in mesh.devices.flatten() if d.id not in lost]
    if not survivors:
        raise ReshrinkError("no surviving devices")
    axes = tuple(mesh.axis_names)
    old = tuple(int(s) for s in mesh.devices.shape)
    sizes = dict(zip(axes, old))
    pod0 = sizes.get("pod", 1)
    data0 = sizes.get("data", 1)
    model0 = sizes.get("model", 1)
    n = len(survivors)

    def batch_ok(p, d):
        ndp = p * d
        return global_batch % ndp == 0 and global_batch >= ndp

    candidates = []
    for m in range(model0, 0, -1):               # model degrades last ...
        for p in range(pod0, 0, -1):             # ... pod second ...
            for d in range(data0, 0, -1):        # ... data first
                if p * d * m <= n and batch_ok(p, d):
                    candidates.append((m, p, d))
    if not candidates:
        raise ReshrinkError(
            f"cannot re-factorize {dict(sizes)} over {n} survivors with "
            f"global_batch={global_batch}")
    # preference: max model, then max pod, then max data — exactly the
    # degradation order (the sort above already emits in that order)
    m, p, d = candidates[0]

    shape = []
    for a in axes:
        shape.append({"pod": p, "data": d, "model": m}.get(a, 1))
    shape = tuple(shape)
    count = int(np.prod(shape))
    devs = np.array(survivors[:count], dtype=object).reshape(shape)
    new_mesh = jax.sharding.Mesh(devs, axes)
    if params is not None and cfg is not None:
        validate_param_divisibility(params, cfg, new_mesh)
    degraded = tuple(a for a, o, s in zip(axes, old, shape) if s < o)
    return ReshrinkPlan(mesh=new_mesh, old_shape=old, new_shape=shape,
                        axis_names=axes, lost_ids=tuple(sorted(lost)),
                        n_idle=n - count, degraded_axes=degraded)
