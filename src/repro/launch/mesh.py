"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run process forces
512 host devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types when this jax supports them
    (>= 0.5); plain mesh construction otherwise."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return make_mesh_compat((n_data, n_model), ("data", "model"))
