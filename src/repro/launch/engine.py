"""Unified TL training engine: one driver for all three execution modes.

Before this module the repo ran a TL step three disjoint ways:

* **simulator serial** — ``TLOrchestrator.train_batch``: the protocol
  simulator's per-virtual-batch round (node visits -> centralized BP);
* **simulator pipelined** — ``repro.core.pipeline``: the double-buffered
  visit-producer / BP-consumer epoch engine over the same orchestrator;
* **production jit** — ``launch/train.py``'s bare ``jax.jit`` loop with no
  mesh, no shardings, no donation, and a host sync every step.

``Engine`` unifies them behind one API::

    Engine(model, cfg, opt, mesh, shape).run(loader, steps)

**Production mode** (``mode="production"``, the default) drives the pjit TL
step (``repro.core.tl_step``) the way the 512-chip dry-run lowers it:

* the step is jitted once with :func:`train_shardings` in/out shardings on
  the given mesh and the params/opt_state buffers donated — identical on
  the (1,1)/(2,2) debug meshes, the forced-8-device CPU host mesh, and the
  multi-pod (pod, data, model) production mesh;
* ``pipeline=True`` ports the simulator's producer/consumer split to the
  device path: while step k's update runs, a background producer thread
  already assembles virtual batch k+1 from the loader and
  ``jax.device_put``\\ s its node-major shards with the ``tokens_pspec``
  NamedSharding — a 2-deep host->device prefetch queue (the double buffer:
  the batch being consumed plus the batch in flight), bounded by a slot
  semaphore so at most ``PREFETCH_DEPTH`` batches ever materialize ahead.
  ``pipeline=False`` reproduces the historical
  strictly batch-serial driver (dispatch, wait for the step, only then
  touch the loader) as the equivalence oracle and benchmark baseline;
* losses stay device-resident for the whole run; the host materializes a
  value only at ``log_every`` boundaries and at the end, so logging never
  blocks the prefetch queue;
* ``reassembly`` ("none" | "xla" | "pallas") puts the orchestrator's
  virtual-batch reassembly on the pjit hot path (``repro.core.tl_step``):
  the loader's ``positions`` (global batch positions of the node-major
  rows) are converted — per data shard — into shard-local rank perms, so
  the in-loss scatter runs under a ``shard_map`` over the (pod, data) axes
  with zero collective traffic; ``"pallas"`` lowers it through the fused
  ``repro.kernels.vb_scatter`` kernel instead of XLA's generic scatter.

**Simulator mode** (``mode="sim"``) wraps ``TLOrchestrator`` and routes
``pipeline=True`` through ``repro.core.pipeline`` — the engine is then a
thin facade so quickstart-style scripts and the production driver share one
entrypoint.

Equivalence guarantees (enforced by ``tests/test_engine.py``):

* production ``pipeline=True`` and ``pipeline=False`` run the *same* jitted
  step over the *same* batches in the same order — prefetch moves only
  host/transfer timing, so final params match to float32 ULP (in practice
  bit-for-bit) on every mesh;
* simulator ``pipeline=True`` is the lossless reordering proven by
  ``tests/test_pipelined_equivalence.py``.

**Elastic mode** (``elastic=True``, production only) wraps the pjit path in
a supervision loop that turns a lost chip from a fatal crash into a
bounded-cost recovery: ``device_faults`` (a
``repro.launch.elastic.DeviceFaultInjector``) injects seeded/scripted chip
kills and hung collectives at the host boundary, every step is issued under
a ``watchdog_s`` deadline (a hung collective is *classified* as a lost
device instead of stalling forever), and on detection the engine

1. re-factorizes the mesh over the surviving devices
   (``launch.mesh.plan_reshrink`` — data axis degrades first, validated
   against ``param_specs`` divisibility),
2. rolls back to the newest valid checkpoint (``ckpt_dir`` is therefore
   required; a step-0 anchor is written before the first step),
3. re-shards params/opt_state onto the new mesh's ``NamedSharding``\\ s and
   re-jits the step,
4. replays the loader deterministically to the rollback step and resumes.

The recovery guarantee is exact: post-recovery training on the shrunken
mesh is **bit-equal** to a fresh run launched from that checkpoint on that
mesh (``tests/test_elastic.py``) — the loader is a pure function of its
seed and every replayed batch flows through the same re-jitted step.
Without ``elastic=True`` an armed injector still detects (kill raises,
the watchdog still fires within its deadline) but the ``DeviceLost``
propagates as a loud failure instead of recovering.  Each recovery's
detect/plan/restore/rejit/replay cost lands in ``Engine.recovery_log``
(the ``elastic_recovery`` benchmark column and the
``runtime_model.recovery_cost`` term measure exactly this).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core.tl_step import make_train_step, train_shardings
from repro.dist.sharding import tokens_pspec
from repro.launch.elastic import (HANG, DeviceFaultInjector, DeviceFaultSpec,
                                  DeviceLost, RecoveryReport, WatchdogTimeout,
                                  call_with_deadline, simulate_hang)


@dataclass
class EngineResult:
    """What one ``Engine.run`` produced.  ``losses`` is host-materialized
    exactly once, at the end of the run."""
    losses: np.ndarray
    steps: int
    wall_s: float
    params: Any
    opt_state: Any = None
    stats: Optional[List] = None          # sim mode: flat StepStats list
    epoch_stats: Optional[List[List]] = None
    recovery: Optional[List] = None       # elastic mode: RecoveryReports

    @property
    def steps_per_s(self) -> float:
        return self.steps / self.wall_s if self.wall_s else float("inf")


class Engine:
    """Unified TL training driver (see module docstring).

    Production-mode knobs: ``pipeline`` (2-deep device prefetch vs strictly
    batch-serial), ``remat_mode``, ``donate``, ``log_every``,
    ``reassembly`` ("none" | "xla" | "pallas" — in-loss virtual-batch
    reassembly with shard-local perms).

    Sim-mode knobs (forwarded to ``TLOrchestrator``): ``batch_size``,
    ``transport``, ``fused``, ``cache_model_per_epoch``, ``seed``; the
    shared ``pipeline`` flag selects the double-buffered epoch engine and
    ``reassembly`` the orchestrator's scatter strategy.  ``wire``
    ("off" | "int8" | "fp8") + ``wire_ef`` build a visit-payload
    :class:`~repro.core.transport.WirePolicy` transport (sim-only; model
    parameters never quantize; mutually exclusive with ``transport``).
    """

    PREFETCH_DEPTH = 2          # double buffer: consumed batch + in-flight

    def __init__(self, model, cfg: ModelConfig, opt, mesh=None,
                 shape: Optional[InputShape] = None, *,
                 mode: str = "production", pipeline: bool = True,
                 remat_mode: str = "tl", donate: bool = True,
                 microbatch: int = 1, log_every: int = 0,
                 reassembly: str = "none",
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 ckpt_keep: int = 0, elastic: bool = False,
                 device_faults=None, watchdog_s: float = 60.0,
                 batch_size: int = 64, transport=None, fused: bool = True,
                 cache_model_per_epoch: bool = False, seed: int = 0,
                 wire: str = "off", wire_ef: bool = False,
                 hierarchy: int = 0):
        if mode not in ("production", "sim"):
            raise ValueError(f"unknown engine mode: {mode!r}")
        if mode == "production" and (mesh is None or shape is None):
            raise ValueError("production mode needs a mesh and an InputShape")
        if wire != "off" and mode != "sim":
            raise ValueError(
                "wire compression is simulator-only for now: the production "
                "pjit path has no Transport to carry the WirePolicy")
        if wire != "off" and transport is not None:
            raise ValueError("pass either wire=... or a pre-built transport, "
                             "not both")
        if reassembly not in ("none", "xla", "pallas"):
            raise ValueError(f"unknown reassembly strategy: {reassembly!r}")
        if hierarchy < 0:
            raise ValueError(f"hierarchy must be >= 0, got {hierarchy}")
        if hierarchy and mode != "sim":
            raise ValueError(
                "hierarchy= (two-tier orchestration fan-out) is "
                "simulator-only: the production pjit path shards one flat "
                "step instead of nesting orchestrators")
        if hierarchy and pipeline:
            raise ValueError(
                "hierarchy= needs pipeline=False: the subtree lanes are "
                "the overlap; the double-buffered epoch engine on top "
                "would double-book the clock")
        if elastic and mode != "production":
            raise ValueError("elastic mode is production-only")
        if elastic and not ckpt_dir:
            raise ValueError(
                "elastic mode needs a ckpt_dir: the newest checkpoint is the "
                "rollback anchor every recovery restores from")
        self.model = model
        self.cfg = cfg
        self.opt = opt
        self.mesh = mesh
        self.shape = shape
        self.mode = mode
        self.pipeline = pipeline
        self.remat_mode = remat_mode
        self.donate = donate
        self.microbatch = microbatch
        self.log_every = log_every
        # reassembly: "none" | "xla" | "pallas" — production mode scatters
        # the virtual batch into shuffled order inside the loss (see module
        # docstring); sim mode forwards the strategy to TLOrchestrator
        # ("none" keeps the orchestrator's default xla scatter)
        self.reassembly = reassembly
        # step-boundary checkpointing (repro.checkpoint): production mode
        # saves {params, opt_state} every ckpt_every steps; sim mode saves
        # the orchestrator's full resume state at every epoch boundary.
        # restore() + run() replays the remaining batches — the loader and
        # the orchestrator's plan are pure functions of their seeds, so a
        # killed run resumes ULP-identically (tests/test_faults.py)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        # ckpt_keep > 0 bounds the checkpoint dir: after every save the GC
        # retains the `keep` newest valid steps (repro.checkpoint
        # .gc_checkpoints) — never the step a live resume/rollback depends on
        self.ckpt_keep = ckpt_keep
        # ----- elastic supervision (see module docstring + launch.elastic)
        self.elastic = elastic
        if isinstance(device_faults, DeviceFaultSpec):
            device_faults = DeviceFaultInjector(device_faults)
        self.device_faults = device_faults
        self.watchdog_s = watchdog_s
        self.recovery_log: List[RecoveryReport] = []
        # a deterministic drill would re-fire every time the replay revisits
        # its step — fire each (step, device, kind) verdict at most once so
        # every recovery makes monotone progress
        self._fired_faults = set()
        self._protect_steps = set()
        # the watchdog deadline models the *steady-state* step clock; the
        # first step after every (re-)jit also pays an unbounded compile, so
        # it runs unsupervised and the deadline arms from the next step
        self._jit_warm = False
        self._loss_acc = {}            # step -> device loss (replays overwrite)
        self._pending_report = None    # RecoveryReport awaiting rejit/replay timings
        # caller-supplied run metadata stamped into every checkpoint's
        # extra dict (e.g. the CLI's total-step budget, which fixes the LR
        # schedule); surfaced back on restore() as .restored_meta so the
        # caller can refuse a resume whose run config would silently change
        # the arithmetic (bit-identity holds only for identical configs)
        self.ckpt_meta: Optional[dict] = None
        self.restored_meta: Optional[dict] = None
        self._start_step = 0
        self._sim_resume = None
        # sim-mode state
        self.batch_size = batch_size
        if wire != "off":
            from repro.core.transport import Transport, WirePolicy
            transport = Transport(
                wire=WirePolicy.visits(wire, error_feedback=wire_ef))
        self.wire = wire
        self.wire_ef = wire_ef
        self.transport = transport
        self.fused = fused
        self.cache_model_per_epoch = cache_model_per_epoch
        self.seed = seed
        # hierarchy > 0: sim mode builds a HierarchicalOrchestrator with
        # that many subtrees (0 = flat single orchestrator)
        self.hierarchy = hierarchy
        self.orchestrator = None
        self._sim_shards = None
        # production-mode state
        self.params = None
        self.opt_state = None
        self._step_fn = None
        self._batch_shardings = None
        self._zero_embeds = None
        self._n_perm_shards = 1

    # ------------------------------------------------------------ lifecycle
    def init(self, key) -> "Engine":
        """Initialize params (+ optimizer state in production mode)."""
        self.params = self.model.init(key)
        if self.mode == "production":
            self.opt_state = self.opt.init(self.params)
        self._initialized = True
        return self

    def n_params(self) -> int:
        assert self.params is not None, "call init(key) first"
        return sum(p.size for p in jax.tree.leaves(self.params))

    # ------------------------------------------------- checkpoint / resume
    def save_ckpt(self, params, opt_state, step: int) -> str:
        from repro.checkpoint import gc_checkpoints, save_checkpoint
        extra = {"step": step}
        extra.update(self.ckpt_meta or {})
        path = save_checkpoint(self.ckpt_dir, step,
                               {"params": params, "opt_state": opt_state},
                               extra=extra)
        if self.ckpt_keep:
            gc_checkpoints(self.ckpt_dir, self.ckpt_keep,
                           protect=self._protect_steps)
        return path

    def restore(self, ckpt_dir: Optional[str] = None,
                step: Optional[int] = None) -> int:
        """Load a step-boundary checkpoint and arm the next ``run`` to
        resume from it.  Returns the global step the run will continue at.

        Production mode: params/opt_state are restored bit-exactly (npz is
        lossless for every dtype the checkpointer handles) and ``run``
        skips the already-consumed loader batches — the loader is a pure
        function of its seed, so the replayed tail is exactly the killed
        run's remainder and the final state is ULP-identical to an
        uninterrupted run.  Sim mode: the orchestrator's full resume state
        (including the mid-epoch traversal cursor) is loaded lazily at the
        next ``run``."""
        from repro.checkpoint import latest_step, load_checkpoint
        ckpt_dir = ckpt_dir or self.ckpt_dir
        if ckpt_dir is None:
            raise ValueError("no ckpt_dir configured or given")
        if self.mode == "sim":
            self._sim_resume = (ckpt_dir, step)
            got = step if step is not None else latest_step(ckpt_dir)
            if got is None:
                raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
            return int(got)
        if self.params is None:
            self.init(jax.random.PRNGKey(0))       # structure template
        tree = {"params": self.params, "opt_state": self.opt_state}
        arrays, meta = load_checkpoint(ckpt_dir, tree, step)
        self.params = arrays["params"]
        self.opt_state = arrays["opt_state"]
        self.restored_meta = dict(meta["extra"])
        self._start_step = int(meta["extra"]["step"])
        # the live resume replays from this step: the GC must never take it
        self._protect_steps.add(self._start_step)
        return self._start_step

    # ------------------------------------------------- production: jit once
    def _build_step(self):
        """jit the TL step with train_shardings in/out + donated state."""
        if self._step_fn is not None:
            return self._step_fn
        cfg, mesh, shape = self.cfg, self.mesh, self.shape
        reassemble = self.reassembly != "none"
        step = make_train_step(self.model, cfg, self.opt,
                               remat_mode=self.remat_mode,
                               microbatch=self.microbatch,
                               reassembly=self.reassembly, mesh=mesh)
        with mesh:
            in_sh, out_sh = train_shardings(
                self.params, self.opt_state, cfg, mesh, shape,
                with_embeds=bool(cfg.frontend), with_perm=reassemble)
        donate = (0, 1) if self.donate else ()
        self._step_fn = jax.jit(step, in_shardings=in_sh,
                                out_shardings=out_sh, donate_argnums=donate)
        tok = tokens_pspec(mesh, shape.global_batch)
        sh = {"tokens": NamedSharding(mesh, tok),
              "targets": NamedSharding(mesh, tok)}
        if reassemble:
            sh["perm"] = NamedSharding(mesh, P(tok[0]))
            # perms must be local to each of the n_dp batch shards (a
            # permutation of the shard's own row block) so the shard_map'd
            # scatter in the loss never crosses a chip boundary
            self._n_perm_shards = 1
            if tok[0] is not None:
                for a in (tok[0] if isinstance(tok[0], tuple) else (tok[0],)):
                    self._n_perm_shards *= mesh.shape[a]
        if cfg.frontend:
            sh["embeds"] = NamedSharding(mesh, P(tok[0], None, None))
            # frontend stubs are constant zeros: materialize the sharded
            # device array once, not one host alloc + transfer per batch
            self._zero_embeds = jax.device_put(
                jnp.zeros((shape.global_batch, cfg.frontend_tokens,
                           cfg.d_model)), sh["embeds"])
        self._batch_shardings = sh
        return self._step_fn

    def _local_perm(self, positions):
        """Global batch positions -> shard-local rank perm.

        Block j (one data shard's rows) gets the ranks of its rows' global
        positions: scattering by them orders each shard's slice by global
        batch position — the orchestrator's reassembly restricted to the
        shard, with no cross-shard movement."""
        pos = np.asarray(positions)
        blocks = pos.reshape(self._n_perm_shards, -1)
        return np.argsort(np.argsort(blocks, axis=1),
                          axis=1).reshape(-1).astype(np.int32)

    def _put_batch(self, host_batch):
        """host batch -> node-major device shards under tokens_pspec."""
        cfg, sh = self.cfg, self._batch_shardings
        host_batch = dict(host_batch)
        # the loader's global row positions only matter when reassembling;
        # they become the shard-local perm (and never ship to the device
        # themselves)
        positions = host_batch.pop("positions", None)
        if self.reassembly != "none":
            if positions is None:
                raise ValueError(
                    "reassembly needs the loader to emit 'positions' "
                    "(global batch positions of the node-major rows); "
                    "VirtualBatchLoader does so by default")
            host_batch["perm"] = self._local_perm(positions)
        out = {k: jax.device_put(np.asarray(v), sh[k])
               for k, v in host_batch.items()}
        if cfg.frontend and "embeds" not in out:
            out["embeds"] = self._zero_embeds
        return out

    def _device_batches(self, host_batches: Iterable):
        """The producer half: a 2-deep host->device prefetch queue.

        A background producer thread assembles batch k+1 from the loader and
        ``device_put``\\ s its shards while the main thread drives step k —
        so the ingest+transfer cost rides in the shadow of device compute
        even on backends whose chained dispatch is effectively synchronous
        (XLA:CPU).  ``PREFETCH_DEPTH`` slots bound the batches materialized
        ahead of the consumer (the double buffer: the batch being consumed
        plus the batch being prefetched) — the producer blocks on the slot
        semaphore *before* assembling, so memory stays bounded.  Order is a
        FIFO queue and every batch flows through the same jitted step, so
        the arithmetic is exactly the serial path's.
        """
        import queue
        import threading

        q: queue.Queue = queue.Queue()
        slots = threading.Semaphore(self.PREFETCH_DEPTH)
        stop = threading.Event()

        def produce():
            try:
                for hb in host_batches:
                    slots.acquire()
                    if stop.is_set():       # consumer died: don't keep
                        return              # materializing device batches
                    q.put(("item", self._put_batch(hb)))
                q.put(("done", None))
            except BaseException as e:  # noqa: BLE001 — re-raised by consumer
                q.put(("error", e))

        threading.Thread(target=produce, daemon=True,
                         name="tl-engine-prefetch").start()
        try:
            while True:
                kind, val = q.get()
                if kind == "done":
                    return
                if kind == "error":
                    raise val
                yield val
                slots.release()
        finally:
            # consumer abandoned mid-run (step raised, generator closed):
            # wake a slot-parked producer so the thread exits instead of
            # leaking with up to PREFETCH_DEPTH device batches pinned
            stop.set()
            slots.release()

    def _run_production(self, loader, steps: int) -> EngineResult:
        if self.params is None:
            if getattr(self, "_initialized", False):
                # a previous run failed after handing its buffers to the
                # donated step; silently restarting from PRNGKey(0) would
                # discard all prior progress without a trace
                raise RuntimeError(
                    "engine state was lost by a failed run; call "
                    "init(key) (or assign params/opt_state) before rerunning")
            self.init(jax.random.PRNGKey(0))
        self._loss_acc = {}
        if self.elastic:
            return self._run_production_elastic(loader, steps)
        return self._production_pass(loader, steps)

    # --------------------------------------------- elastic fault detection
    def _maybe_inject(self, step: int):
        """Consult the fault injector for this step over the *current*
        mesh's device ids; a non-OK verdict raises :class:`DeviceLost`.

        A kill raises before the step is issued (the state is not donated
        for that step — exactly a runtime device error surfacing at
        dispatch).  A hang is only observable through the watchdog: the
        simulated never-completing collective runs under
        :func:`call_with_deadline` and the resulting timeout is classified
        as a lost device.  Each verdict fires at most once (the fired-set),
        so the post-recovery replay makes progress past the drill step."""
        inj = self.device_faults
        if inj is None:
            return
        for d in self.mesh.devices.flatten():
            kind = inj.decide(step, d.id)
            if kind is None or (step, d.id, kind) in self._fired_faults:
                continue
            self._fired_faults.add((step, d.id, kind))
            t0 = time.perf_counter()
            if kind == HANG:
                if not self.watchdog_s or self.watchdog_s <= 0:
                    raise RuntimeError(
                        f"hang injected at step {step} on device {d.id} but "
                        "no watchdog is armed (watchdog_s <= 0): the run "
                        "would stall forever inside the collective")
                try:
                    call_with_deadline(
                        simulate_hang, (self.watchdog_s,),
                        deadline_s=self.watchdog_s,
                        what=f"step {step} (injected hang)")
                except WatchdogTimeout:
                    pass                      # classified: fall through
            err = DeviceLost(step, d.id, kind)
            err.detect_s = time.perf_counter() - t0
            raise err

    def _run_production_elastic(self, loader, steps: int) -> EngineResult:
        from repro.checkpoint import latest_step
        if iter(loader) is loader:
            raise ValueError(
                "elastic mode needs a re-iterable loader (got a bare "
                "iterator): recovery replays the stream from the rollback "
                "step, which requires restarting iteration")
        # step-0 anchor: a device lost before the first periodic checkpoint
        # must still have a rollback point
        if latest_step(self.ckpt_dir) is None:
            self.save_ckpt(self.params, self.opt_state, self._start_step)
            self._protect_steps.add(self._start_step)
        t_wall = time.perf_counter()
        while True:
            try:
                res = self._production_pass(loader, steps)
            except DeviceLost as e:
                self.recovery_log.append(self._recover(e))
                continue
            res.wall_s = time.perf_counter() - t_wall   # includes recoveries
            res.recovery = list(self.recovery_log)
            return res

    def _recover(self, e: DeviceLost) -> RecoveryReport:
        """One detect→reshrink→rollback→re-shard→re-jit recovery.

        Bit-equality contract: everything that defines the arithmetic after
        recovery — the checkpoint state, the reshrunk mesh's shardings, the
        re-jitted step, the replayed batches — is exactly what a fresh run
        launched from that checkpoint on that mesh would use, so the two are
        indistinguishable (``tests/test_elastic.py`` asserts bit-equal)."""
        from repro.checkpoint import latest_step, load_checkpoint
        from repro.launch.mesh import plan_reshrink
        t0 = time.perf_counter()
        lost = e.device
        if lost < 0:
            # the watchdog classified a stall but nothing identified the
            # chip (a real un-injected hang): drop the highest-id device —
            # a real deployment would health-probe first, but shrinking by
            # one guarantees forward progress either way
            lost = max(d.id for d in self.mesh.devices.flatten())
        # params may be donated-deleted buffers here; shapes/dtypes survive
        # deletion, which is all the divisibility validation needs
        template = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), self.params)
        plan = plan_reshrink(self.mesh, [lost],
                             global_batch=self.shape.global_batch,
                             params=template, cfg=self.cfg)
        t_plan = time.perf_counter()

        rollback = latest_step(self.ckpt_dir)
        if rollback is None:
            raise RuntimeError(
                "device lost but no valid checkpoint remains to roll back "
                f"to under {self.ckpt_dir}") from e
        self._protect_steps.add(rollback)
        old_shape = tuple(int(s) for s in self.mesh.devices.shape)
        self.mesh = plan.mesh
        # everything derived from the old mesh is now invalid
        self._step_fn = None
        self._batch_shardings = None
        self._zero_embeds = None
        self._jit_warm = False

        # restore + re-shard onto the shrunken mesh's NamedShardings
        names = jax.tree.map(lambda p: np.zeros((), np.float32),
                             {"params": self.params,
                              "opt_state": self.opt_state})
        arrays, _ = load_checkpoint(self.ckpt_dir, names, rollback)
        with self.mesh:
            in_sh, _ = train_shardings(
                arrays["params"], arrays["opt_state"], self.cfg, self.mesh,
                self.shape, with_embeds=bool(self.cfg.frontend),
                with_perm=self.reassembly != "none")
        self.params = jax.device_put(arrays["params"], in_sh[0])
        self.opt_state = jax.device_put(arrays["opt_state"], in_sh[1])
        jax.block_until_ready((self.params, self.opt_state))
        self._start_step = int(rollback)
        t_restore = time.perf_counter()

        report = RecoveryReport(
            step=e.step, device=e.device, cause=e.cause,
            rollback_step=int(rollback),
            rollback_depth=int(e.step - rollback),
            old_mesh_shape=old_shape, new_mesh_shape=plan.new_shape,
            detect_s=getattr(e, "detect_s", 0.0),
            plan_s=t_plan - t0, restore_s=t_restore - t_plan,
            extra={"degraded_axes": list(plan.degraded_axes),
                   "n_idle": plan.n_idle, "dropped_device": int(lost)})
        # rejit_s (first post-recovery step: recompile for the new mesh) and
        # replay_s (loader fast-forward) are filled in by the next pass
        self._pending_report = report
        return report

    def _production_pass(self, loader, steps: int) -> EngineResult:
        step_fn = self._build_step()
        start = self._start_step
        if start >= steps:
            # keep the resume cursor armed: disarming before raising would
            # turn a caught-and-retried run into a silent from-step-0
            # replay on top of the restored parameters
            raise ValueError(
                f"resume step {start} is past the requested budget "
                f"steps={steps}: nothing to run")
        self._start_step = 0

        # deterministic loader replay: skip the already-consumed prefix
        # eagerly (and time it — this is the recovery model's replay term)
        it = iter(loader)
        t_replay = time.perf_counter()
        try:
            for _ in range(start):
                next(it)
        except StopIteration:
            pass
        if self._pending_report is not None:
            self._pending_report.replay_s = time.perf_counter() - t_replay

        def host_batches():
            # steps is the *global* budget: a resumed run replays (skips)
            # the first `start` loader batches, then runs the rest
            for i, hb in enumerate(it, start=start):
                if i >= steps:
                    return
                yield hb

        if self.pipeline:
            batches = self._device_batches(host_batches())
        else:
            # strictly batch-serial oracle: the loader is not touched while
            # a step is in flight (the consumer blocks below)
            batches = map(self._put_batch, host_batches())

        # device scalars keyed by global step, one host sync at the end;
        # a replayed step simply overwrites its pre-rollback entry
        losses = self._loss_acc
        params, opt_state = self.params, self.opt_state
        self.params = self.opt_state = None    # donated: drop stale refs
        armed = self.device_faults is not None or self.elastic
        deadline = self.watchdog_s if (armed and self.watchdog_s
                                       and self.watchdog_s > 0) else None
        t0 = time.perf_counter()
        k = start
        try:
            for k, batch in enumerate(batches, start=start):
                self._maybe_inject(k)          # raises DeviceLost on verdict
                t_step = time.perf_counter()
                if deadline is not None and self._jit_warm:
                    # supervised dispatch: a hung collective surfaces as a
                    # WatchdogTimeout instead of stalling the run forever.
                    # The warmup step (fresh jit: unbounded compile time)
                    # runs unsupervised so a slow compile is never
                    # misclassified as a hang.
                    params, opt_state, loss = call_with_deadline(
                        step_fn, (params, opt_state, batch),
                        deadline_s=deadline, what=f"step {k}")
                else:
                    params, opt_state, loss = step_fn(params, opt_state,
                                                      batch)
                self._jit_warm = True
                if self._pending_report is not None:
                    # first post-recovery step: its wall time is the re-jit
                    # cost (recompile for the reshrunk mesh)
                    jax.block_until_ready(loss)
                    self._pending_report.rejit_s = (time.perf_counter()
                                                    - t_step)
                    self._pending_report = None
                losses[k] = loss
                if not self.pipeline:
                    jax.block_until_ready(loss)
                if self.log_every and k % self.log_every == 0:
                    # the only mid-run host sync, at the caller's cadence
                    print(f"step {k:4d} loss {float(loss):.4f} "
                          f"({time.perf_counter() - t0:.1f}s)")
                if (self.ckpt_dir and self.ckpt_every
                        and (k + 1) % self.ckpt_every == 0):
                    # step-boundary checkpoint: forces a host sync of the
                    # state at the caller's chosen cadence (the prefetch
                    # queue keeps producing meanwhile)
                    self.save_ckpt(params, opt_state, k + 1)
            jax.block_until_ready(params)
        except WatchdogTimeout as t:
            # a real (un-injected) stall: classify as a lost device with no
            # identified chip; the elastic loop (or the caller) decides what
            # to drop.  The worker thread still holds the donated buffers,
            # so the engine state is gone either way — exactly a real hang.
            err = DeviceLost(k, -1, HANG)
            err.detect_s = deadline or 0.0
            raise err from t
        finally:
            # on failure these may point at donated (deleted) buffers — a
            # later use then raises loudly instead of silently restarting
            self.params, self.opt_state = params, opt_state
        wall = time.perf_counter() - t0
        order = sorted(losses)
        loss_arr = (np.asarray(jax.device_get([losses[i] for i in order]),
                               np.float32)
                    if order else np.zeros((0,), np.float32))
        return EngineResult(losses=loss_arr, steps=len(order), wall_s=wall,
                            params=params, opt_state=opt_state)

    # ---------------------------------------------------------- sim facade
    def _run_sim(self, shards, epochs: int) -> EngineResult:
        from repro.core.node import TLNode
        from repro.core.orchestrator import TLOrchestrator
        from repro.core.plan import PlanSpec
        from repro.core.transport import Transport

        if self.orchestrator is not None and shards is not self._sim_shards:
            # the cached orchestrator's TLNodes were built from the first
            # run's shards; silently training on those while the caller
            # hands in different data would fit the wrong dataset
            raise ValueError(
                "sim-mode engine is bound to the shards of its first run; "
                "pass the same shards object to continue training, or build "
                "a fresh Engine for a different dataset")
        if self.orchestrator is None:
            self._sim_shards = shards
            nodes = [TLNode(i, self.model, s.x, s.y, jit_visits=self.fused)
                     for i, s in enumerate(shards)]
            common = dict(
                plan=PlanSpec(seed=self.seed, batch_size=self.batch_size),
                fused=self.fused, donate=False,
                cache_model_per_epoch=self.cache_model_per_epoch,
                reassembly=("xla" if self.reassembly == "none"
                            else self.reassembly))
            if self.hierarchy:
                from repro.core.hierarchy import HierarchicalOrchestrator
                self.orchestrator = HierarchicalOrchestrator(
                    self.model, nodes, self.opt,
                    self.transport or Transport(),
                    n_subtrees=self.hierarchy, **common)
            else:
                self.orchestrator = TLOrchestrator(
                    self.model, nodes, self.opt,
                    self.transport or Transport(),
                    pipelined=self.pipeline, **common)
            if self.params is not None:       # caller-provided init (eq. 13)
                self.orchestrator.params = self.params
                self.orchestrator.opt_state = self.opt.init(self.params)
            else:
                self.orchestrator.initialize(jax.random.PRNGKey(self.seed))
        orch = self.orchestrator

        start_batch = 0
        if self._sim_resume is not None:
            ckpt_dir, step = self._sim_resume
            self._sim_resume = None
            start_batch = orch.restore(ckpt_dir, step)

        epoch_stats, t0 = [], time.perf_counter()
        for e in range(epochs):
            # first (possibly partial) epoch resumes at the checkpoint's
            # mid-epoch traversal cursor; later epochs run in full
            epoch_stats.append(orch.train_epoch(
                start_batch=start_batch if e == 0 else 0))
            if self.ckpt_dir:
                orch.save(self.ckpt_dir)     # epoch-boundary checkpoint
        wall = time.perf_counter() - t0
        flat = [s for ep in epoch_stats for s in ep]
        self.params = orch.params
        return EngineResult(
            losses=np.asarray([s.loss for s in flat], np.float32),
            steps=len(flat), wall_s=wall, params=orch.params,
            opt_state=orch.opt_state, stats=flat, epoch_stats=epoch_stats)

    # ----------------------------------------------------------------- run
    def run(self, loader, steps: Optional[int] = None, *,
            epochs: Optional[int] = None) -> EngineResult:
        """Drive training.

        Production mode: ``loader`` yields host batch dicts (e.g. a
        ``VirtualBatchLoader``); ``steps`` bounds the run.  Sim mode:
        ``loader`` is a sequence of per-node shards (anything with ``.x`` /
        ``.y``) and ``epochs`` counts orchestrator epochs.
        """
        if self.mode == "production":
            if steps is None:
                raise ValueError("production mode needs steps=")
            if epochs is not None:
                raise ValueError("production mode counts steps, not epochs")
            return self._run_production(loader, steps)
        if steps is not None:
            raise ValueError("sim mode counts epochs, not steps")
        return self._run_sim(loader, epochs if epochs is not None else 1)
