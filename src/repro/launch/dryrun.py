import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first initialization).  Do not move them.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and emit
roofline artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape train_4k --mesh single [--remat tl] [--out artifacts/]

Exit code 0 and a JSON artifact mean the sharding config is coherent for the
production mesh: GSPMD found a partitioning, the collective schedule exists,
and memory/cost analyses were extracted.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.roofline import Roofline, model_flops, summarize
from repro.configs import get_config, get_shape
from repro.core.tl_step import (make_serve_step, make_train_step,
                                serve_shardings, train_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import abstract_cache, abstract_params, input_specs
from repro.models import build_model
from repro.optim import adafactor

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)


def lower_one(arch: str, shape_name: str, mesh_kind: str, remat: str = "tl",
              dtype=jnp.bfloat16, extra_tags=None, microbatch: int = 1,
              cache_seq_shard: bool = False, activation_constraints: bool = False,
              serve_fsdp=None, moe_ep: bool = False):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind == "decode" and shape.seq_len > 40_000 \
            and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "full-attention arch: long-context decode is "
                          "quadratic by design (DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    model = build_model(cfg)
    params = abstract_params(model, dtype)
    t0 = time.time()

    from repro.dist.constraints import set_activation_mesh
    from repro.dist.sharding import batch_axes
    if activation_constraints:
        set_activation_mesh(batch_axes(mesh))
    if moe_ep:
        from repro.models.moe import set_expert_parallel_mesh
        set_expert_parallel_mesh(mesh)

    with mesh:
        if shape.kind == "train":
            opt = adafactor(1e-3)
            opt_state = jax.eval_shape(opt.init, params)
            step = make_train_step(model, cfg, opt, remat_mode=remat,
                                   microbatch=microbatch)
            in_sh, out_sh = train_shardings(
                params, opt_state, cfg, mesh, shape,
                with_embeds=bool(cfg.frontend))
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(
                params, opt_state, input_specs(cfg, shape, dtype))
        elif shape.kind == "prefill":
            specs = input_specs(cfg, shape, dtype)
            cache = abstract_cache(model, shape.global_batch, shape.seq_len,
                                   dtype)
            in_sh, out_sh = serve_shardings(params, cache, cfg, mesh, shape,
                                            cache_seq_shard=cache_seq_shard,
                                            fsdp=serve_fsdp)
            pf = lambda p, c, tok, extra=None: model.prefill(p, c, tok, extra)
            args = (params, cache, specs["tokens"])
            in_shardings = (in_sh[0], in_sh[1], None)
            if "embeds" in specs:
                args = args + (specs["embeds"],)
                in_shardings = in_shardings + (None,)
            lowered = jax.jit(pf, in_shardings=in_shardings,
                              out_shardings=out_sh).lower(*args)
        else:  # decode
            specs = input_specs(cfg, shape, dtype)
            cache = abstract_cache(model, shape.global_batch, shape.seq_len,
                                   dtype)
            in_sh, out_sh = serve_shardings(params, cache, cfg, mesh, shape,
                                            cache_seq_shard=cache_seq_shard,
                                            fsdp=serve_fsdp)
            step = make_serve_step(model, cfg)
            lowered = jax.jit(step, in_shardings=(in_sh[0], in_sh[1],
                                                  in_sh[2], in_sh[3]),
                              out_shardings=out_sh).lower(
                params, cache, specs["token"], specs["cache_len"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # jax<=0.4 returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()

    # cost_analysis counts scan (while) bodies once; the HLO analyzer
    # multiplies by trip counts — use it for the roofline, keep raw XLA
    # numbers as a cross-check
    from repro.analysis.hlo_flops import analyze
    costs = analyze(hlo)
    coll = {k: int(v) for k, v in costs.coll.items()}
    flops = float(costs.flops)
    bytes_acc = float(costs.hbm_bytes)
    raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    raw_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    mem_fields = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_fields[f] = int(v)

    peak = (mem_fields.get("argument_size_in_bytes", 0)
            + mem_fields.get("temp_size_in_bytes", 0)
            + mem_fields.get("output_size_in_bytes", 0)
            - mem_fields.get("alias_size_in_bytes", 0))

    r = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        flops_per_chip=flops, bytes_per_chip=bytes_acc,
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops_global=model_flops(cfg, shape),
        peak_memory_per_chip=float(peak),
    )
    out = r.to_dict()
    out.update(status="ok", remat=remat, microbatch=microbatch,
               cache_seq_shard=cache_seq_shard,
               activation_constraints=activation_constraints,
               memory_analysis=mem_fields,
               t_lower_s=t_lower, t_compile_s=t_compile,
               hlo_lines=hlo.count("\n"),
               xla_cost_analysis={"flops": raw_flops,
                                  "bytes_accessed": raw_bytes},
               extra_tags=extra_tags or {})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--remat", default="tl", choices=["tl", "none", "dots"])
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--act-constraints", action="store_true")
    ap.add_argument("--no-serve-fsdp", action="store_true")
    ap.add_argument("--moe-ep", action="store_true")
    args = ap.parse_args()

    try:
        art = lower_one(args.arch, args.shape, args.mesh, args.remat,
                        microbatch=args.microbatch,
                        cache_seq_shard=args.cache_seq_shard,
                        activation_constraints=args.act_constraints,
                        serve_fsdp=False if args.no_serve_fsdp else None,
                        moe_ep=args.moe_ep)
    except Exception as e:  # noqa: BLE001 — report compile failures as data
        art = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}

    os.makedirs(args.out, exist_ok=True)
    name = f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)

    if art["status"] == "ok":
        print("memory_analysis:", art["memory_analysis"])
        print("cost_analysis: flops=%.3e bytes=%.3e" %
              (art["flops_per_chip"], art["bytes_per_chip"]))
        print(summarize(art))
    else:
        print(art["status"], art.get("reason", art.get("error", "")))
    print("artifact:", path)
    return 0 if art["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    raise SystemExit(main())
