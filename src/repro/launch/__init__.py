from repro.launch.engine import Engine, EngineResult
from repro.launch.mesh import (make_debug_mesh, make_host_mesh,
                               make_multipod_debug_mesh,
                               make_production_mesh, resolve_mesh)

__all__ = ["Engine", "EngineResult", "make_production_mesh",
           "make_debug_mesh", "make_host_mesh", "make_multipod_debug_mesh",
           "resolve_mesh"]
