"""Elastic supervision for the production pjit path: device-loss detection.

PR 5 made the *traversal wire* survive (dropped WAN payloads, stragglers),
but the production engine's whole global step lives on one mesh — TL's
centralized-BP design — so a single lost chip stalls every node's
contribution and, without supervision, hangs the run inside a collective
forever.  This module supplies the detection half of the elastic engine
(``repro.launch.engine`` owns the recovery orchestration):

* :class:`DeviceFaultSpec` / :class:`DeviceFaultInjector` — seeded,
  **order-independent** per-``(step, device)`` fault verdicts (the same
  counter-based-RNG design as ``repro.core.faults.FaultInjector``): chip
  *kill* (the runtime raises immediately, like a real XLA device error) and
  *hang* (a collective that never completes — detectable only by deadline).
  Scripted drills (``kill-device:STEP[:DEVICE]``) ride the same interface
  for deterministic CI recovery drills.
* :func:`call_with_deadline` — the per-step watchdog: runs the step
  dispatch+sync on a worker thread and raises :class:`WatchdogTimeout` when
  the deadline passes, so a hung collective is *classified* as a lost
  device instead of stalling the run.
* :class:`DeviceLost` — the one exception the engine's recovery loop
  catches: detection (kill or watchdog-classified hang) normalized to
  ``(step, device, cause)``.
* :class:`RecoveryReport` — the per-recovery cost breakdown
  (detect/plan/restore/rejit/replay wall-clock + rollback depth) that backs
  the ``elastic_recovery`` benchmark column and the runtime model's
  recovery-cost term (``repro.core.runtime_model.recovery_cost``).

The injector simulates faults *at the host boundary* (verdicts consulted as
each step is issued) because a CPU test host cannot actually unplug an XLA
device; on a real TPU slice the same ``DeviceLost`` is raised from the
runtime's device error instead, and everything downstream — watchdog,
reshrink, rollback, replay — is identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

# the watchdog lives in repro.core.watchdog (shared with the serving
# engine's supervision loop, PR 9); re-exported here unchanged so all
# PR 6-era imports keep working
from repro.core.watchdog import WatchdogTimeout as WatchdogTimeout
from repro.core.watchdog import call_with_deadline as call_with_deadline
from repro.core.watchdog import simulate_hang as simulate_hang

KILL = "kill"        # chip dies: the step raises immediately
HANG = "hang"        # collective never completes: only a deadline sees it


class DeviceLost(RuntimeError):
    """A device was lost at ``step`` (detected by error or by watchdog).

    ``cause`` is :data:`KILL` (the runtime raised) or :data:`HANG` (the
    watchdog deadline fired and classified the stall as a lost device)."""

    def __init__(self, step: int, device: int, cause: str):
        super().__init__(
            f"device {device} lost at step {step} ({cause}): the mesh must "
            "be reshrunk and the run rolled back to its last checkpoint")
        self.step = int(step)
        self.device = int(device)
        self.cause = cause


@dataclass(frozen=True)
class Drill:
    """One scripted fault: ``kind`` at ``step`` on ``device``."""

    kind: str                    # KILL | HANG
    step: int
    device: int = 0

    def __post_init__(self):
        if self.kind not in (KILL, HANG):
            raise ValueError(f"unknown drill kind: {self.kind!r}")
        if self.step < 0 or self.device < 0:
            raise ValueError("drill step/device must be >= 0")


def parse_drill(text: str) -> Drill:
    """CLI drill syntax: ``kill-device:STEP[:DEVICE]`` /
    ``hang-device:STEP[:DEVICE]`` (device defaults to 0)."""
    parts = text.split(":")
    head = parts[0]
    if head not in ("kill-device", "hang-device") or len(parts) not in (2, 3):
        raise ValueError(
            f"bad drill {text!r}: expected kill-device:STEP[:DEVICE] or "
            "hang-device:STEP[:DEVICE]")
    try:
        step = int(parts[1])
        device = int(parts[2]) if len(parts) == 3 else 0
    except ValueError:
        raise ValueError(f"bad drill {text!r}: STEP/DEVICE must be integers")
    return Drill(KILL if head == "kill-device" else HANG, step, device)


@dataclass(frozen=True)
class DeviceFaultSpec:
    """Seeded device-fault distribution + scripted drills.

    Probabilities are per ``(step, device)``: each pair draws its own
    verdict from a counter-based RNG keyed ``(seed, step, device)``, so the
    verdict never depends on how many other pairs were consulted first —
    a re-planned/replayed run re-draws identical faults (the invariant
    ``tests/test_elastic.py`` pins, mirroring ``core.faults``)."""

    kill_prob: float = 0.0
    hang_prob: float = 0.0
    seed: int = 0
    drills: Tuple[Drill, ...] = ()

    def __post_init__(self):
        if not 0.0 <= self.kill_prob < 1.0:
            raise ValueError("kill_prob must be in [0, 1)")
        if not 0.0 <= self.hang_prob < 1.0:
            raise ValueError("hang_prob must be in [0, 1)")
        if self.kill_prob + self.hang_prob >= 1.0:
            raise ValueError("kill_prob + hang_prob must be < 1")


class DeviceFaultInjector:
    """Order-independent seeded device-fault verdicts (see the spec).

    ``decide(step, device)`` is a pure function of ``(seed, step, device)``;
    ``first_fault(step, n_devices)`` scans devices in index order and
    returns the first non-OK verdict (device index order is canonical, so
    the scan itself is deterministic too).  Scripted drills win over the
    seeded draw and fire exactly once per ``(step, device)``.
    """

    def __init__(self, spec: DeviceFaultSpec):
        self.spec = spec

    def decide(self, step: int, device: int) -> Optional[str]:
        s = self.spec
        for d in s.drills:
            if d.step == step and d.device == device:
                return d.kind
        if s.kill_prob == 0.0 and s.hang_prob == 0.0:
            return None
        u = float(np.random.default_rng(
            (s.seed, int(step), int(device))).random())
        if u < s.kill_prob:
            return KILL
        if u < s.kill_prob + s.hang_prob:
            return HANG
        return None

    def first_fault(self, step: int, n_devices: int
                    ) -> Optional[Tuple[int, str]]:
        for device in range(n_devices):
            kind = self.decide(step, device)
            if kind is not None:
                return device, kind
        return None


@dataclass
class RecoveryReport:
    """Cost breakdown of one detect→reshape→restore→replay recovery."""

    step: int                    # the step the device was lost at
    device: int
    cause: str                   # KILL | HANG
    rollback_step: int           # checkpoint step the run rolled back to
    rollback_depth: int          # steps of lost progress (step - rollback)
    old_mesh_shape: Tuple[int, ...] = ()
    new_mesh_shape: Tuple[int, ...] = ()
    detect_s: float = 0.0        # issue -> DeviceLost classified
    plan_s: float = 0.0          # mesh reshrink planning
    restore_s: float = 0.0       # checkpoint load + re-shard onto new mesh
    rejit_s: float = 0.0         # first step on the new mesh (recompile)
    replay_s: float = 0.0        # loader fast-forward to the rollback step
    extra: dict = field(default_factory=dict)

    @property
    def total_s(self) -> float:
        return (self.detect_s + self.plan_s + self.restore_s
                + self.rejit_s + self.replay_s)

    def as_dict(self) -> dict:
        return {
            "step": self.step, "device": self.device, "cause": self.cause,
            "rollback_step": self.rollback_step,
            "rollback_depth": self.rollback_depth,
            "old_mesh_shape": list(self.old_mesh_shape),
            "new_mesh_shape": list(self.new_mesh_shape),
            "detect_s": round(self.detect_s, 4),
            "plan_s": round(self.plan_s, 4),
            "restore_s": round(self.restore_s, 4),
            "rejit_s": round(self.rejit_s, 4),
            "replay_s": round(self.replay_s, 4),
            "total_s": round(self.total_s, 4),
        }
