"""End-to-end TL training driver (CPU-runnable at reduced scale).

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --nodes 4 --batch 8 --seq 64

Wires together: synthetic corpus -> node shards -> virtual-batch loader
(Algorithm 1) -> production TL train step (remat-from-X^(1) + node-axis
gradient aggregation) -> optimizer -> checkpointing.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.tl_step import make_train_step
from repro.data.pipeline import VirtualBatchLoader, shard_corpus, synthetic_corpus
from repro.models import build_model
from repro.optim import adamw, warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="tl", choices=["tl", "none", "dots"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M nodes={args.nodes}")

    opt = adamw(warmup_cosine(args.lr, 10, args.steps), clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt, remat_mode=args.remat))

    docs = synthetic_corpus(args.nodes * 64, args.seq, cfg.vocab_size, seed=1)
    shards = shard_corpus(docs, args.nodes)
    loader = VirtualBatchLoader(shards, args.batch, seed=0)

    losses = []
    t0 = time.time()
    for step, batch in enumerate(loader):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.frontend:
            batch["embeds"] = jnp.zeros(
                (batch["tokens"].shape[0], cfg.frontend_tokens, cfg.d_model))
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if step % args.log_every == 0:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.1f}s)")
    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(start {np.mean(losses[:5]):.4f})")
    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps,
                               {"params": params, "opt": opt_state})
        print("checkpoint:", path)
    return losses


if __name__ == "__main__":
    main()
