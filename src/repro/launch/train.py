"""End-to-end TL training CLI — a thin shim over ``repro.launch.engine``.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --nodes 4 --batch 8 --seq 64 --mesh debug --pipeline

Wires together: synthetic corpus -> node shards -> virtual-batch loader
(Algorithm 1) -> ``Engine`` -> checkpointing.  The engine owns everything
the old driver got wrong: the step is jitted once with ``train_shardings``
in/out shardings and donated params/opt_state on a real mesh (``--mesh
{debug,host,production}``), batches prefetch host->device through a 2-deep
queue while the previous step runs (``--pipeline``, default; ``--no-
pipeline`` is the strictly batch-serial oracle), losses stay
device-resident until log boundaries — no per-step host sync — and the
virtual batch is reassembled into shuffled order inside the compiled step
(``--reassembly {xla,pallas}``: generic scatter vs the fused Pallas
vb_scatter kernel, shard-local perms under shard_map).

The three execution modes and their equivalence guarantees are documented
in ``repro.launch.engine``; the pipelined and serial paths produce
float32-ULP-identical parameters (``tests/test_engine.py``).

Fault tolerance: ``--ckpt-every N`` writes a step-boundary checkpoint into
``--ckpt`` every N steps (``--ckpt-keep N`` bounds the directory to the N
newest valid steps), and ``--resume`` restores the latest one — the loader
is a pure function of its seed, so the resumed run replays exactly the
killed run's remaining batches and finishes ULP-identical to an
uninterrupted run (``tests/test_faults.py``).

Elastic production engine: ``--elastic`` arms the device-loss supervision
loop (``repro.launch.elastic`` + ``Engine``): a lost chip or hung
collective (watchdog deadline ``--watchdog-s``) triggers mesh reshrink +
checkpoint rollback + deterministic replay instead of a crash.
``--drill kill-device:STEP[:DEV]`` / ``hang-device:STEP[:DEV]`` injects a
scripted fault for recovery drills; with ``--elastic`` the CLI then
*verifies the recovery guarantee* — it re-runs fresh from the rollback
checkpoint on the shrunken mesh and asserts the final parameters are
bit-equal, printing ``RECOVERY_DRILL bit_equal=true`` (the CI
``recovery-drill`` job greps exactly this).  A drill without ``--elastic``
fails loudly with the ``DeviceLost`` diagnostic — never a silent hang.

Compressed traversal wire: ``--mode sim --wire {int8,fp8} [--wire-ef]``
runs the protocol simulator with the visit-payload lane quantized
(per-row absmax, ``repro.kernels.act_compress``) and prints the measured
per-tag raw-vs-wire byte ratio from the transport; ``--wire-ef`` adds the
error-feedback accumulator (lossless-in-the-limit).  Model parameters
never quantize in any configuration.
"""
from __future__ import annotations

import argparse
import sys
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.pipeline import VirtualBatchLoader, shard_corpus, synthetic_corpus
from repro.launch.engine import Engine
from repro.launch.mesh import resolve_mesh
from repro.models import build_model
from repro.optim import adamw, warmup_cosine


def _run_sim(args):
    """Protocol-simulator run (``--mode sim``): DATRET on the
    TLOrchestrator via the Engine facade, with the wire-compression lane
    live — prints the per-tag raw-vs-wire byte accounting from the
    transport so ``--wire int8 --wire-ef`` shows the measured bandwidth
    win (model parameters always ship exact)."""
    from repro.configs.paper_models import DATRET
    from repro.core.baselines import ShardData
    from repro.models.small import SmallModel
    from repro.optim import sgd

    r = np.random.default_rng(5)
    shards = [ShardData(
        r.normal(size=(64,) + DATRET.in_shape).astype(np.float32),
        r.integers(0, DATRET.n_classes, 64)) for _ in range(args.nodes)]
    engine = Engine(SmallModel(DATRET), DATRET, sgd(0.05), mode="sim",
                    pipeline=args.pipeline and not args.hierarchy,
                    batch_size=32, seed=0, hierarchy=args.hierarchy,
                    wire=args.wire, wire_ef=args.wire_ef)
    result = engine.run(shards, epochs=args.epochs)
    tr = engine.orchestrator.transport
    print(f"mode=sim arch=datret nodes={args.nodes} epochs={args.epochs} "
          f"hierarchy={args.hierarchy} wire={args.wire} ef={args.wire_ef}")
    for tag in sorted(tr.bytes_sent):
        raw, wire = tr.raw_bytes.get(tag, 0), tr.bytes_sent[tag]
        print(f"wire[{tag}]: raw={raw} wire={wire} "
              f"ratio={raw / max(wire, 1):.2f}x")
    losses = result.losses.tolist()
    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(start {np.mean(losses[:5]):.4f})")
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="tl", choices=["tl", "none", "dots"])
    ap.add_argument("--reassembly", default="xla",
                    choices=["xla", "pallas"],
                    help="virtual-batch reassembly on the hot path: XLA's "
                         "generic scatter or the fused Pallas vb_scatter "
                         "kernel (shard-local perms under shard_map)")
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "host", "production"])
    ap.add_argument("--multi-pod", action="store_true",
                    help="with --mesh production: the 2x16x16 "
                         "(pod, data, model) mesh")
    ap.add_argument("--pipeline", action="store_true", default=True,
                    help="2-deep host->device batch prefetch (default)")
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false",
                    help="strictly batch-serial loading (the equivalence "
                         "oracle)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a step-boundary checkpoint into --ckpt every "
                         "N steps (0: only the final checkpoint)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt; the "
                         "run replays the loader tail and finishes "
                         "ULP-identical to an uninterrupted run")
    ap.add_argument("--ckpt-keep", type=int, default=0,
                    help="retain only the N newest valid checkpoints after "
                         "every save (0: keep everything); the step a live "
                         "resume/rollback depends on is never collected")
    ap.add_argument("--elastic", action="store_true",
                    help="device-loss supervision: watchdog detection, mesh "
                         "reshrink over the survivors, checkpoint rollback, "
                         "deterministic replay (see repro.launch.elastic)")
    ap.add_argument("--drill", default=None,
                    help="scripted fault injection: kill-device:STEP[:DEV] "
                         "or hang-device:STEP[:DEV]; with --elastic the run "
                         "recovers and the CLI verifies bit-equality against "
                         "a fresh run from the rollback checkpoint")
    ap.add_argument("--watchdog-s", type=float, default=60.0,
                    help="per-step watchdog deadline (seconds): a step that "
                         "exceeds it is classified as a lost device")
    ap.add_argument("--halt-at", type=int, default=0,
                    help="crash drill: stop after this many global steps "
                         "without finishing the --steps budget (the LR "
                         "schedule and checkpoints stay those of the full "
                         "budget, exactly like a real mid-run kill)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mode", default="production",
                    choices=["production", "sim"],
                    help="production: pjit engine on a device mesh; sim: "
                         "the protocol simulator (TLOrchestrator), where "
                         "the wire-compression lane is live")
    ap.add_argument("--epochs", type=int, default=3,
                    help="sim mode: orchestrator epochs")
    ap.add_argument("--hierarchy", type=int, default=0,
                    help="sim mode: two-tier orchestration fan-out — "
                         "number of subtrees (0: flat). Implies "
                         "--no-pipeline: the subtree lanes are the overlap")
    ap.add_argument("--wire", default="off", choices=["off", "int8", "fp8"],
                    help="visit-payload wire codec in the sim transport "
                         "(X^(1)/δ^(L)/∂X^(1)/∂W^(1) quantize per-row; "
                         "model parameters never do)")
    ap.add_argument("--wire-ef", action="store_true",
                    help="error-feedback accumulator on the wire lane: "
                         "each send compresses x + residual and carries "
                         "the quantization error forward "
                         "(lossless-in-the-limit)")
    args = ap.parse_args(argv)
    if args.wire != "off" and args.mode != "sim":
        ap.error("--wire is simulator-only for now: pass --mode sim (the "
                 "production pjit path has no Transport wire)")
    if args.wire_ef and args.wire == "off":
        ap.error("--wire-ef needs --wire {int8,fp8}")
    if args.mode == "sim":
        return _run_sim(args)
    if args.resume and not args.ckpt:
        ap.error("--resume needs --ckpt")
    if args.ckpt_every and not args.ckpt:
        ap.error("--ckpt-every needs --ckpt")
    if args.ckpt_keep and not args.ckpt:
        ap.error("--ckpt-keep needs --ckpt")
    if args.elastic and not args.ckpt:
        # recovery needs a rollback anchor; a drill run doesn't need the
        # checkpoints to outlive the process
        args.ckpt = tempfile.mkdtemp(prefix="tl_elastic_ckpt_")
        print(f"--elastic without --ckpt: rollback anchors in {args.ckpt}")
    drill = None
    if args.drill:
        from repro.launch.elastic import DeviceFaultSpec, parse_drill
        try:
            drill = DeviceFaultSpec(drills=(parse_drill(args.drill),))
        except ValueError as e:
            ap.error(str(e))

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    mesh = resolve_mesh(args.mesh, multi_pod=args.multi_pod)
    shape = InputShape("cli_train", args.seq, args.batch, "train")
    opt = adamw(warmup_cosine(args.lr, 10, args.steps), clip_norm=1.0)

    engine = Engine(model, cfg, opt, mesh, shape,
                    pipeline=args.pipeline, remat_mode=args.remat,
                    reassembly=args.reassembly, log_every=args.log_every,
                    ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
                    ckpt_keep=args.ckpt_keep, elastic=args.elastic,
                    device_faults=drill, watchdog_s=args.watchdog_s)
    # the LR schedule is a function of the run config (--steps fixes the
    # cosine horizon, --lr the peak): stamp it into every checkpoint so a
    # resume under a *different* config fails loudly instead of silently
    # replaying different arithmetic (bit-identity needs identical configs)
    # nodes/batch/seq shape the synthetic corpus and loader stream, so they
    # are part of the resume contract too
    engine.ckpt_meta = {"arch": cfg.name, "steps": args.steps,
                        "lr": args.lr, "seed": 0, "nodes": args.nodes,
                        "batch": args.batch, "seq": args.seq}
    if args.resume:
        at = engine.restore()
        got = engine.restored_meta or {}
        for key, want in engine.ckpt_meta.items():
            if key in got and got[key] != want:
                ap.error(
                    f"--resume config mismatch: checkpoint was written by a "
                    f"run with {key}={got[key]!r}, this run has {key}="
                    f"{want!r} — the LR schedule/data order would diverge "
                    "from the killed run (pass the original flags)")
        if at >= args.steps:
            ap.error(f"checkpoint is already at step {at} of the --steps "
                     f"{args.steps} budget: nothing to resume")
        print(f"resumed from step {at}")
    else:
        at = 0
        engine.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={engine.n_params()/1e6:.1f}M "
          f"nodes={args.nodes} mesh={args.mesh}{mesh.devices.shape} "
          f"pipeline={args.pipeline} reassembly={args.reassembly}")

    docs = synthetic_corpus(args.nodes * 64, args.seq, cfg.vocab_size, seed=1)
    shards = shard_corpus(docs, args.nodes)
    loader = VirtualBatchLoader(shards, args.batch, seed=0)

    budget = min(args.halt_at, args.steps) if args.halt_at else args.steps
    try:
        result = engine.run(loader, steps=budget)
    except Exception as e:
        from repro.launch.elastic import DeviceLost
        if isinstance(e, DeviceLost):
            # un-recovered device loss (no --elastic): fail loudly with the
            # diagnostic instead of a hang or a bare traceback
            print(f"FATAL: {e}\n       rerun with --elastic to recover "
                  "(reshrink + rollback + replay)", file=sys.stderr)
            raise SystemExit(2)
        raise
    for rec in result.recovery or ():
        print("recovery:", rec.as_dict())
    losses = result.losses.tolist()
    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(start {np.mean(losses[:5]):.4f}) "
          f"{result.steps_per_s:.2f} steps/s")
    if args.ckpt:
        # same layout as the engine's step-boundary checkpoints, so a
        # --halt-at (or crashed-after-save) run's final checkpoint is
        # --resume-able under the same flags; a *completed* budget cannot
        # be extended — the config guard above refuses a changed --steps
        path = engine.save_ckpt(result.params, result.opt_state,
                                at + result.steps)
        print("checkpoint:", path)

    if args.elastic and args.drill and result.recovery:
        # verify the recovery guarantee end-to-end: a *fresh* engine on the
        # final (shrunken) mesh, restored from the rollback checkpoint and
        # run over the same loader, must produce bit-equal parameters —
        # post-recovery training is indistinguishable from a clean launch
        rollback = result.recovery[-1].rollback_step
        oracle = Engine(model, cfg, opt, engine.mesh, shape,
                        pipeline=args.pipeline, remat_mode=args.remat,
                        reassembly=args.reassembly, ckpt_dir=args.ckpt)
        oracle.restore(step=rollback)
        fresh = oracle.run(loader, steps=budget)
        bit_equal = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(result.params),
                            jax.tree.leaves(fresh.params)))
        print(f"RECOVERY_DRILL bit_equal={str(bit_equal).lower()} "
              f"rollback_step={rollback} "
              f"mesh={tuple(int(s) for s in engine.mesh.devices.shape)}")
        if not bit_equal:
            print("FATAL: post-recovery parameters diverge from a fresh run "
                  "off the rollback checkpoint — the recovery guarantee is "
                  "broken", file=sys.stderr)
            raise SystemExit(3)
    return losses


if __name__ == "__main__":
    main()
