"""Activation-sharding constraints for the jit/GSPMD TL step.

Model code calls :func:`constrain_batch` on intermediate activations; when an
activation mesh has been installed (globally via :func:`set_activation_mesh`
or scoped via :func:`activation_sharding`) this lowers to
``with_sharding_constraint(x, P(batch_axes, None, ...))`` — pinning the
leading (virtual-batch / TL-node) dim to the data axes so GSPMD never
re-lays-out activations mid-stack.  With no mesh installed it is the
identity (returns its argument unchanged), so eager CPU tests and the
protocol simulator pay nothing.

API surface:

* ``set_activation_mesh(axes_or_mesh_or_None)`` — install/clear the batch
  axes globally (``launch.dryrun`` passes ``batch_axes(mesh)``).
* ``activation_sharding(axes)`` — context manager, restores on exit.
* ``constrain_batch(x)`` — constrain ``x``'s leading dim; identity when off.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

_ACT_AXES: Optional[Tuple[str, ...]] = None


def set_activation_mesh(axes) -> None:
    """Install the mesh axes activations shard their batch dim over.

    ``axes`` may be a tuple of axis names, a ``Mesh`` (its batch axes are
    extracted), or ``None`` to disable constraints.
    """
    global _ACT_AXES
    if axes is None:
        _ACT_AXES = None
    elif isinstance(axes, (tuple, list)):
        _ACT_AXES = tuple(axes) or None
    else:                                   # a Mesh
        from repro.dist.sharding import batch_axes
        _ACT_AXES = batch_axes(axes) or None


@contextlib.contextmanager
def activation_sharding(axes: Optional[Sequence[str]]):
    """Scoped :func:`set_activation_mesh`; restores the previous value."""
    global _ACT_AXES
    prev = _ACT_AXES
    set_activation_mesh(axes)
    try:
        yield
    finally:
        _ACT_AXES = prev


def constrain_batch(x):
    """Constrain ``x``'s leading dim to the installed batch axes.

    Identity (``is x``) when no activation mesh is installed, so this is
    free on the eager / single-device path.
    """
    if _ACT_AXES is None:
        return x
    spec = P(_ACT_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
