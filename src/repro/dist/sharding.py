"""Sharding rules for the production TL step (GSPMD / jit path).

API surface (consumed by ``repro.core.tl_step``, the models, and
``repro.launch.dryrun``):

* :func:`batch_axes`       — mesh axes the virtual batch shards over,
  in order: ``("pod", "data")`` on a multi-pod mesh, ``("data",)`` otherwise.
* :func:`tokens_pspec`     — ``PartitionSpec`` for ``(B, S)`` step inputs.
* :func:`cache_pspec`      — base spec for KV / recurrent-state cache leaves.
* :func:`param_pspec`      — spec for one parameter leaf from its tree path.
* :func:`param_specs`      — ``param_pspec`` mapped over a whole pytree with
  the mesh's axis sizes enforced (an axis is only assigned to a dim it
  divides exactly, so every spec is always realizable).
* :func:`_mesh_sizes`      — ``{axis_name: size}`` for a (concrete or
  abstract) mesh; exposed for optimizer-slot spec derivation.

Placement policy (Megatron + optional FSDP):

=====================  ===========================================
leaf                   spec (before divisibility filtering)
=====================  ===========================================
``embed``  (V, d)      ``P("model", dp)``      — vocab-sharded
``head``   (d, V)      ``P(dp, "model")``      — column-parallel
``w_o|w_down|w_out``   ``P("model", dp)``      — row-parallel
other 2-D weights      ``P(dp, "model")``      — column-parallel
expert stacks (E,i,o)  ``P("model", dp, None)`` — expert-sharded
1-D / scalars          replicated
=====================  ===========================================

``dp`` is :func:`batch_axes` and is only used when FSDP is enabled
(``fsdp=None`` defaults to on for training; serving passes ``fsdp=False``
for TP-only weights with no per-step all-gathers).  Leaves living under a
``"cycles"`` stack carry a leading scan axis that is never sharded.

**MoE exception (routing-stability layout).**  MoE routing is discrete:
``top_k`` over router logits.  Any contraction split — a row-parallel psum,
FSDP partial sums, or expert-axis batched-matmul regrouping — perturbs the
logits at the ULP level and can flip an expert assignment, which moves the
loss by whole percents (measured: ~2e-2 on the reduced DeepSeek-V3 vs ~1e-7
for dense archs).  Architectures with ``cfg.moe`` therefore get an
*all-column* layout: every weight shards only its output dim on "model"
(GSPMD inserts activation all-gathers instead of psums, keeping every
contraction whole and the routing bit-stable), expert stacks shard
``d_ff_expert``/``d_model`` rather than the expert axis, and FSDP is
disabled.  Expert *parallelism* over E lives in the explicit shard_map path
(``repro.models.moe_ep``), which controls its own collectives.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from jax.sharding import PartitionSpec as P

# Row-parallel projections: the *input* (contraction) dim is model-sharded so
# the preceding column-parallel matmul's output shards flow straight in.
_ROW_PARALLEL = ("w_o", "w_down", "w_out")


def _mesh_sizes(mesh) -> Dict[str, int]:
    """``{axis_name: size}``.  Works for ``Mesh`` and ``AbstractMesh``."""
    return dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names)))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the (virtual) batch dimension shards over, major-to-minor."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axes_size(entry, sizes: Dict[str, int]) -> Optional[int]:
    """Product of mesh-axis sizes for one spec entry; None if an axis is
    absent from the mesh."""
    axes = entry if isinstance(entry, tuple) else (entry,)
    total = 1
    for a in axes:
        if a not in sizes:
            return None
        total *= sizes[a]
    return total


def _filter_divisible(spec, shape, sizes: Optional[Dict[str, int]]):
    """Drop spec entries whose mesh-axis product doesn't divide the dim."""
    if sizes is None:
        return P(*spec)
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        n = _axes_size(entry, sizes)
        if n is None or n == 0 or dim % n != 0:
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


def spec_divisible(shape, spec, mesh) -> bool:
    """True iff ``spec`` materializes on ``mesh`` for an array of ``shape``:
    every non-None entry's mesh-axis product divides its dim exactly.

    This is the commit criterion the elastic reshrink planner
    (``repro.launch.mesh.validate_param_divisibility``) checks before
    re-sharding onto a shrunken mesh — ``param_pspec`` *filters*
    non-dividing axes silently (the right behavior when choosing a layout),
    but a reshrink must instead *refuse* a mesh whose layout contract the
    sharding layer couldn't honor."""
    sizes = _mesh_sizes(mesh) if not isinstance(mesh, dict) else mesh
    for dim, entry in zip(shape, tuple(spec)):
        if entry is None:
            continue
        n = _axes_size(entry, sizes)
        if n is None or n == 0 or dim % n != 0:
            return False
    return True


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)


def param_pspec(path, leaf, cfg, *, axis_sizes: Optional[Dict[str, int]] = None,
                fsdp: bool = True) -> P:
    """PartitionSpec for one parameter (or optimizer-slot) leaf.

    ``path`` is a ``tree_map_with_path`` path; the placement rule is chosen
    from the leaf's name and rank (see module docstring).  When
    ``axis_sizes`` is given, any axis that does not divide its dim exactly
    is dropped, so the returned spec always materializes on that mesh.
    """
    names = _path_names(path)
    last = names[-1] if names else ""
    # leaves inside a "cycles" stack carry a leading scan axis (never sharded)
    lead = 1 if "cycles" in names else 0
    shape = leaf.shape
    core = shape[lead:]
    # routing-stability layout: no contraction splits for MoE archs (see
    # module docstring) — all-column TP, no FSDP
    moe_safe = getattr(cfg, "moe", None) is not None
    dp: Optional[Tuple[str, ...]] = None
    if fsdp and not moe_safe:
        if axis_sizes is None:
            dp = ("data",)
        else:
            dp = tuple(a for a in ("pod", "data") if a in axis_sizes) or None

    if len(core) <= 1:                       # norms, biases, gates: replicate
        spec = [None] * len(shape)
        return _filter_divisible(spec, shape, axis_sizes)

    if len(core) == 3 and last in ("w_gate", "w_up", "w_down"):
        # stacked experts (E, d_in, d_out): shard d_out, keep E whole — an
        # E-split regroups the routed batched matmuls and is not bit-stable
        body = [dp, None, "model"]
    elif last == "embed":
        body = ["model", dp] + [None] * (len(core) - 2)
    elif last in _ROW_PARALLEL and not moe_safe:
        body = ["model", dp] + [None] * (len(core) - 2)
    else:                                     # column-parallel default
        body = [dp] + [None] * (len(core) - 2) + ["model"]

    spec = [None] * lead + body
    return _filter_divisible(spec, shape, axis_sizes)


def param_specs(params, cfg, mesh, fsdp: Optional[bool] = None):
    """``param_pspec`` over a whole pytree, with ``mesh``'s sizes enforced.

    ``fsdp=None`` means the default policy (FSDP on); ``fsdp=False`` gives
    TP-only weight sharding for serving.
    """
    import jax
    sizes = _mesh_sizes(mesh)
    use_fsdp = True if fsdp is None else bool(fsdp)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, cfg, axis_sizes=sizes,
                                       fsdp=use_fsdp), params)


def tokens_pspec(mesh, global_batch: int) -> P:
    """Spec for ``(B, S)`` token/target arrays: batch over the data axes when
    they divide it, otherwise replicated.  Always length 2 so callers can
    reuse ``spec[0]`` for other batch-leading inputs."""
    dp = batch_axes(mesh)
    sizes = _mesh_sizes(mesh)
    n_dp = math.prod(sizes[a] for a in dp) if dp else 1
    if dp and n_dp and global_batch % n_dp == 0 and global_batch >= n_dp:
        return P(dp, None)
    return P(None, None)


def cache_pspec(mesh, batch: int, kind: str) -> P:
    """Base spec for cache leaves: ``kind="kv"`` covers (B, S, heads, ...)
    attention caches (heads on "model"); ``kind="state"`` covers recurrent
    state (B, state...) with the first state dim on "model".  Callers pad /
    truncate to the leaf's rank and drop non-dividing axes."""
    dp = batch_axes(mesh)
    sizes = _mesh_sizes(mesh)
    n_dp = math.prod(sizes[a] for a in dp) if dp else 1
    b = dp if (dp and n_dp and batch % n_dp == 0 and batch >= n_dp) else None
    if kind == "kv":
        return P(b, None, "model")
    return P(b, "model")
