"""Distribution layer: mesh-aware sharding rules + activation constraints.

The GSPMD realization of Traversal Learning partitions the virtual batch
over the composite (pod, data) mesh axes — one shard per logical TL node —
and the parameters over ("model", data) Megatron/FSDP-style.  This package
is the single place those decisions live:

``repro.dist.sharding``
    Pure spec producers: :func:`param_specs` / :func:`param_pspec` map a
    parameter pytree to ``PartitionSpec`` s; :func:`tokens_pspec` /
    :func:`cache_pspec` cover step inputs and KV/state caches;
    :func:`batch_axes` names the mesh axes the batch shards over.

``repro.dist.constraints``
    Inside-jit activation sharding hints: :func:`set_activation_mesh` /
    :func:`activation_sharding` install the batch axes globally (or scoped),
    and :func:`constrain_batch` tags intermediate activations so GSPMD keeps
    them batch-sharded instead of inventing its own layout.
"""
from repro.dist import constraints, sharding

__all__ = ["constraints", "sharding"]
