"""RG-LRU diagonal linear recurrence Pallas TPU kernel.

    h_t = a_t ⊙ h_{t-1} + b_t

Grid: (batch, num_chunks) with chunks innermost-sequential; the (W,) hidden
state is carried in VMEM scratch.  Within a chunk the recurrence is unrolled
with ``fori_loop`` over time steps — each step is a (W,)-wide VPU op, with W
(the RG-LRU width, e.g. 4096) lane-aligned to multiples of 128.

VMEM working set: a,b chunks (CK, W) f32 ×2 + state (W,)
  = 2*64*4096*4 + 16 KB ≈ 2.1 MB for CK=64, W=4096 — fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, b_ref, h_ref, h_final_ref, state_scr, *, chunk: int):
    cb = pl.program_id(1)
    ncb = pl.num_programs(1)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0].astype(jnp.float32)            # (CK, W)
    b = b_ref[0].astype(jnp.float32)            # (CK, W)

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = h

    @pl.when(cb == ncb - 1)
    def _emit():
        h_final_ref[0] = h.astype(h_final_ref.dtype)


def rglru_scan_b(a, b, *, chunk: int = 64, interpret=None):
    """a, b: (B, S, W) with a ∈ (0,1).  Returns h (B,S,W), h_final (B,W)."""
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S, W = a.shape
    assert S % chunk == 0
    grid = (B, S // chunk)
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    h, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, W), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, W), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, W), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, W), lambda i, c: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[_scratch((W,), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return h, hT


def _scratch(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.VMEM(shape, dtype)
