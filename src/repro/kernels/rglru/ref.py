"""Pure-jnp oracle for the RG-LRU scan (associative scan)."""
import jax


def rglru_ref(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan.  a, b: (B, S, W)."""
    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
