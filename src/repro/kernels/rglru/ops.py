"""Jit'd wrapper for the RG-LRU recurrence kernel."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.rglru.kernel import rglru_scan_b


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _rglru_scan(a, b, *, chunk: int, interpret: bool):
    B, S, W = a.shape
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    h, hT = rglru_scan_b(a, b, chunk=chunk, interpret=interpret)
    return h[:, :S], hT


def rglru_scan(a, b, *, chunk: int = 64, interpret=None):
    """a, b: (B, S, W).  Pads S to the chunk size and strips the pad.
    ``interpret`` resolves via ``REPRO_PALLAS_INTERPRET`` (see
    ``repro.kernels.resolve_interpret``)."""
    return _rglru_scan(a, b, chunk=chunk,
                       interpret=resolve_interpret(interpret))
