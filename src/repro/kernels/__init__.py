"""Pallas TPU kernels for the compute hot-spots of the assigned architectures.

Each kernel ships as a package: ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp
oracle).  On this CPU container kernels run in ``interpret=True`` mode; the
BlockSpecs are written for TPU v5e VMEM.
"""
