"""Pallas TPU kernels for the compute hot-spots of the assigned architectures.

Each kernel ships as a package: ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp
oracle).  BlockSpecs are written for TPU v5e VMEM.

Packages:
  flash_attention — causal/windowed flash attention (GQA), §node-phase FP
  ssd             — Mamba-2 state-space duality chunked scan
  rglru           — RecurrentGemma RG-LRU chunked scan
  act_compress    — per-row absmax int8 wire compression (paper §5.2)
  vb_scatter      — differentiable virtual-batch reassembly: the TL
                    orchestrator's ``out[perm[i]] = payload[i]`` scatter of
                    X^(1)/δ^(L)/∂L∂X^(1) as one multi-ref row-gather pass
                    (custom_vjp; backward is the inverse gather), replacing
                    XLA's generic scatter lowering on the fused-step and
                    production-reassembly hot paths
  paged_attention — paged-KV decode attention for the serving engine: block
                    tables + lengths ride the same scalar-prefetch routing
                    as vb_scatter so K/V BlockSpecs DMA pages straight from
                    the shared pool; online-softmax over pages (flash-style)
                    with an MLA fused-pool mode (V = latent prefix of K)

Interpret mode is resolved process-wide by :func:`resolve_interpret`: the
``REPRO_PALLAS_INTERPRET`` env var (``1``/``0``) overrides, else kernels
interpret on CPU backends and lower for real on TPU hosts — so one test
suite drives both (CI sets nothing and interprets; a TPU host exports
``REPRO_PALLAS_INTERPRET=0`` to exercise Mosaic lowering).
"""
import os


def resolve_interpret(interpret=None) -> bool:
    """Resolve a kernel's Pallas interpret-mode flag.

    Explicit ``interpret=`` wins; else ``REPRO_PALLAS_INTERPRET`` (truthy
    strings enable, ``0``/``false``/``off`` disable); else interpret on CPU
    backends only.  Read at trace time — jitted wrappers resolve *before*
    their jit boundary so an env change takes effect on the next call, not
    the next process.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "off", "no", "")
    import jax
    return jax.default_backend() == "cpu"
