from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_attention_ref

__all__ = ["paged_decode_attention", "paged_decode_attention_ref"]
