"""Dense-attention oracle for paged decode.

Gathers each sequence's pages into a contiguous (B, L, KV, d) view and runs
the exact ``attend_dense`` math from ``repro.models.attention`` (same f32
score cast, same ``NEG_INF`` additive mask, same softmax).  This is both the
kernel's correctness oracle and the serving engine's ``--attention dense``
execution path — the paged machinery (allocator, block tables, page writes)
is identical in both modes; only this attention call differs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                               scale: float, window: int = 0, v_width: int = 0,
                               interpret=None):
    """Pure-jnp reference with the same signature as the kernel wrapper."""
    del interpret
    B, H, d = q.shape
    num_pages, page_size, KV, _ = k_pages.shape
    rep = H // KV
    max_pages = block_tables.shape[1]
    L = max_pages * page_size

    k = k_pages[block_tables].reshape(B, L, KV, d)       # (B, L, KV, d)
    if v_width:
        v = k[..., :v_width]
    else:
        v = v_pages[block_tables].reshape(B, L, KV, v_pages.shape[-1])

    k_pos = jnp.arange(L, dtype=jnp.int32)
    valid = k_pos[None, :] < lengths[:, None]            # (B, L)
    if window > 0:
        valid &= k_pos[None, :] > (lengths[:, None] - 1 - window)
    bias = jnp.where(valid, 0.0, NEG_INF)                # (B, L)

    qg = q.reshape(B, KV, rep, d)
    s = jnp.einsum("bgrd,blgd->bgrl", qg, k).astype(jnp.float32) * scale
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrl,blgd->bgrd", p, v)
    return out.reshape(B, H, v.shape[-1])
