"""Paged-attention decode Pallas TPU kernel.

Single-token decode against a block-table **paged KV cache**: physical pages
of ``page_size`` tokens live in a shared pool ``(num_pages, page, KV, d)``
and each sequence owns an ordered list of page indices (its *block table*).
The kernel reuses the scalar-prefetched index-map routing proven in
``kernels/vb_scatter``: block tables and sequence lengths ride
``PrefetchScalarGridSpec`` so the K/V BlockSpec index maps dereference
``bt_ref[b, j]`` — page ``j`` of sequence ``b`` is DMA'd straight from
wherever it lives in the pool, no gather materialization.

Grid: ``(B, KV_heads, max_pages)`` — pages innermost (sequential on TPU), so
the online-softmax running state (m, l, acc) lives in VMEM scratch across
page iterations, exactly like ``kernels/flash_attention``.  Pages beyond a
sequence's length are skipped via ``@pl.when`` (their DMA still happens but
the FLOPs and state update do not; block tables point such slots at the
allocator's trash page 0, which is never handed out to a sequence).

MLA serving: pass ``v_width > 0`` and no value pool — the value is the
leading ``v_width`` lanes of the key block (the cache stores one fused
``c_kv ‖ k_rope`` pool; values are the latent prefix), so MLA decode reads
each page once.

VMEM per grid step: q tile ``(rep, d)``, one K page ``(page, d)`` (+V for
GQA), acc ``(rep, dv)`` f32 — ≲0.2 MB at page=16, d≤256: far under v5e's
~16 MB, with headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, len_ref, *refs, page_size: int, scale: float,
                         window: int, v_width: int):
    if v_width:                       # fused pool: V = K[:, :v_width] (MLA)
        q_ref, k_ref, o_ref = refs[:3]
        v_ref = None
    else:
        q_ref, k_ref, v_ref, o_ref = refs[:4]
    m_scr, l_scr, acc_scr = refs[-3:]

    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(j * page_size < length)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32)              # (rep, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (page, d)
        rep = q.shape[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (rep, page_size), 1)
        mask = k_pos < length                            # causal: q at length-1
        if window > 0:
            mask &= k_pos > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        v = k[:, :v_width] if v_width else v_ref[0, :, 0, :].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _scratch(shape, dtype):
    try:
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - fallback for CPU interpret mode
        return pl.VMEM(shape, dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           scale: float, window: int = 0, v_width: int = 0,
                           interpret=None):
    """One decode step of every sequence against its paged KV cache.

    q:            (B, H, d)        — this step's query (token at lengths-1)
    k_pages:      (P, page, KV, d) — shared physical page pool
    v_pages:      (P, page, KV, dv) or None when ``v_width`` routes V out of
                  the key pool (MLA fused layout)
    block_tables: (B, max_pages) int32 — page j of seq b is k_pages[bt[b,j]];
                  slots beyond the sequence's pages must point at page 0
    lengths:      (B,) int32 — valid tokens per sequence (incl. this one)

    Returns (B, H, dv).
    """
    interpret = resolve_interpret(interpret)
    B, H, d = q.shape
    num_pages, page_size, KV, _ = k_pages.shape
    rep = H // KV
    max_pages = block_tables.shape[1]
    dv = v_width if v_width else v_pages.shape[-1]

    qg = q.reshape(B, KV, rep, d)
    grid = (B, KV, max_pages)

    q_spec = pl.BlockSpec((1, 1, rep, d),
                          lambda b, h, j, bt, ln: (b, h, 0, 0))
    k_spec = pl.BlockSpec((1, page_size, 1, d),
                          lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0))
    o_spec = pl.BlockSpec((1, 1, rep, dv),
                          lambda b, h, j, bt, ln: (b, h, 0, 0))
    in_specs = [q_spec, k_spec]
    operands = [qg, k_pages]
    if not v_width:
        in_specs.append(pl.BlockSpec((1, page_size, 1, dv),
                                     lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)))
        operands.append(v_pages)

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               scale=scale, window=window, v_width=v_width)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        scratch_shapes=[
            _scratch((rep,), jnp.float32),
            _scratch((rep,), jnp.float32),
            _scratch((rep, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    return out.reshape(B, H, dv)
