"""Pure-jnp oracle for the int8 row quantizer."""
import jax.numpy as jnp


def quantize_rows_ref(x):
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows_ref(q, scale, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[:, None]).astype(out_dtype)
