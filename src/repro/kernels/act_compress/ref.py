"""Pure-jnp oracles for the int8/fp8 row quantizers.

Same formulation as the Pallas kernels (``scale = absmax``, DENOM divides
at dequant time) — see ``kernel.py`` for why that form, not
``scale = absmax/DENOM``, is load-bearing for the error-feedback lane.
"""
import jax.numpy as jnp

from repro.kernels.act_compress.kernel import CODECS, _pin_rails


def quantize_rows_ref(x, codec: str = "int8"):
    qdtype, denom = CODECS[codec]
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, 1e-12)
    u = x / scale[:, None] * denom
    if codec == "int8":
        q = jnp.clip(jnp.round(u), -127, 127).astype(qdtype)
    else:
        q = u.astype(qdtype)
    return q, scale


def dequantize_rows_ref(q, scale, out_dtype=jnp.float32, codec: str = "int8"):
    _, denom = CODECS[codec]
    qf = q.astype(jnp.float32)
    u = _pin_rails(qf, qf / denom, denom)
    return (u * scale[:, None]).astype(out_dtype)
