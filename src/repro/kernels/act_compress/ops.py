"""Jit'd wrappers: compress/decompress arbitrary-shape activations."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.act_compress.kernel import dequantize_rows, quantize_rows


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _compress(x, *, block_rows: int, interpret: bool):
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    R = flat.shape[0]
    pad = (-R) % block_rows
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    q, s = quantize_rows(flat, block_rows=block_rows, interpret=interpret)
    return {"q": q[:R], "scale": s[:R]}


def compress(x, *, block_rows: int = 128, interpret=None):
    """x: (..., D) -> dict(q int8, scale f32, shape).  Rows padded to block.
    ``interpret`` resolves via ``REPRO_PALLAS_INTERPRET`` (see
    ``repro.kernels.resolve_interpret``)."""
    return _compress(x, block_rows=block_rows,
                     interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("shape", "block_rows", "interpret",
                                             "out_dtype"))
def _decompress(payload, shape, *, out_dtype, block_rows: int,
                interpret: bool):
    q, s = payload["q"], payload["scale"]
    R = q.shape[0]
    pad = (-R) % block_rows
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad))
    x = dequantize_rows(q, s, out_dtype=out_dtype, block_rows=block_rows,
                        interpret=interpret)
    return x[:R].reshape(shape)


def decompress(payload, shape, *, out_dtype=jnp.float32, block_rows: int = 128,
               interpret=None):
    """Inverse of :func:`compress` (same interpret-mode resolution)."""
    return _decompress(payload, shape, out_dtype=out_dtype,
                       block_rows=block_rows,
                       interpret=resolve_interpret(interpret))


def compressed_bytes(payload) -> int:
    return payload["q"].size + payload["scale"].size * 4
