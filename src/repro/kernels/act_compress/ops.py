"""Jit'd wrappers: compress/decompress arbitrary-shape activations."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.act_compress.kernel import dequantize_rows, quantize_rows


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def compress(x, *, block_rows: int = 128, interpret: bool = True):
    """x: (..., D) -> dict(q int8, scale f32, shape).  Rows padded to block."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    R = flat.shape[0]
    pad = (-R) % block_rows
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    q, s = quantize_rows(flat, block_rows=block_rows, interpret=interpret)
    return {"q": q[:R], "scale": s[:R]}


@functools.partial(jax.jit, static_argnames=("shape", "block_rows", "interpret",
                                             "out_dtype"))
def decompress(payload, shape, *, out_dtype=jnp.float32, block_rows: int = 128,
               interpret: bool = True):
    q, s = payload["q"], payload["scale"]
    R = q.shape[0]
    pad = (-R) % block_rows
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad))
    x = dequantize_rows(q, s, out_dtype=out_dtype, block_rows=block_rows,
                        interpret=interpret)
    return x[:R].reshape(shape)


def compressed_bytes(payload) -> int:
    return payload["q"].size + payload["scale"].size * 4
