"""Jit'd wrappers: compress/decompress arbitrary-shape activations, plus the
error-feedback accumulator step used by the transport's wire lanes."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.act_compress.kernel import (CODECS, dequantize_rows,
                                               quantize_rows)


def _codec_of(q) -> str:
    """Recover the codec from a payload's wire dtype (int8 | fp8 e4m3)."""
    for name, (dtype, _) in CODECS.items():
        if q.dtype == dtype:
            return name
    raise ValueError(f"payload q has non-wire dtype {q.dtype}")


@functools.partial(jax.jit,
                   static_argnames=("codec", "block_rows", "interpret"))
def _compress(x, *, codec: str, block_rows: int, interpret: bool):
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    R = flat.shape[0]
    pad = (-R) % block_rows
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    q, s = quantize_rows(flat, codec=codec, block_rows=block_rows,
                         interpret=interpret)
    return {"q": q[:R], "scale": s[:R]}


def compress(x, *, codec: str = "int8", block_rows: int = 128,
             interpret=None):
    """x: (..., D) float -> dict(q int8|fp8, scale f32).  Rows padded to
    block.  ``codec`` picks the wire rung ("int8" | "fp8" e4m3, both with
    per-row f32 absmax scales); ``interpret`` resolves via
    ``REPRO_PALLAS_INTERPRET`` (see ``repro.kernels.resolve_interpret``)."""
    if not (hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)):
        raise TypeError(
            "act_compress.compress expects a floating-point tensor, got "
            f"dtype={getattr(x, 'dtype', type(x).__name__)}: quantizing "
            "integer/bool data through the float absmax grid would silently "
            "corrupt it — cast explicitly if that is really intended")
    return _compress(x, codec=codec, block_rows=block_rows,
                     interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("shape", "block_rows",
                                             "interpret", "out_dtype"))
def _decompress(payload, shape, *, out_dtype, block_rows: int,
                interpret: bool):
    q, s = payload["q"], payload["scale"]
    codec = _codec_of(q)
    R = q.shape[0]
    pad = (-R) % block_rows
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad))
    x = dequantize_rows(q, s, codec=codec, out_dtype=out_dtype,
                        block_rows=block_rows, interpret=interpret)
    return x[:R].reshape(shape)


def decompress(payload, shape, *, out_dtype=jnp.float32, block_rows: int = 128,
               interpret=None):
    """Inverse of :func:`compress`; the codec is recovered from the
    payload's wire dtype (same interpret-mode resolution)."""
    return _decompress(payload, shape, out_dtype=out_dtype,
                       block_rows=block_rows,
                       interpret=resolve_interpret(interpret))


def compressed_bytes(payload) -> int:
    """Wire size of one compressed payload: 1 B/element (int8 and fp8 are
    both single-byte dtypes) + one 4 B f32 scale per row."""
    return (payload["q"].size * payload["q"].dtype.itemsize
            + payload["scale"].size * 4)


def ef_compress(x, residual, *, codec: str = "int8", block_rows: int = 128,
                interpret=None):
    """One error-feedback step: compress ``x + residual``, return
    ``(payload, delivered, new_residual)``.

    The residual carries the quantization error *forward*: what this send
    loses, the next send of the same lane adds back in, so a repeatedly
    sent signal is transmitted losslessly in the limit (and a constant
    tensor exactly, from the first send — see ``kernel.py``).  ``residual``
    may be ``None`` (a fresh lane: zero residual).  All EF arithmetic runs
    in f32; ``delivered`` is cast back to ``x.dtype``."""
    xe = x.astype(jnp.float32)
    if residual is not None:
        xe = xe + residual
    payload = compress(xe, codec=codec, block_rows=block_rows,
                       interpret=interpret)
    delivered = decompress(payload, xe.shape, out_dtype=jnp.float32,
                           block_rows=block_rows, interpret=interpret)
    new_residual = xe - delivered
    return payload, delivered.astype(x.dtype), new_residual
