from repro.kernels.act_compress.kernel import CODECS
from repro.kernels.act_compress.ops import (compress, compressed_bytes,
                                            decompress, ef_compress)
from repro.kernels.act_compress.ref import (dequantize_rows_ref,
                                            quantize_rows_ref)

__all__ = ["CODECS", "compress", "decompress", "compressed_bytes",
           "ef_compress", "quantize_rows_ref", "dequantize_rows_ref"]
