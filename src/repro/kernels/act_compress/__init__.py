from repro.kernels.act_compress.ops import compress, compressed_bytes, decompress
from repro.kernels.act_compress.ref import dequantize_rows_ref, quantize_rows_ref

__all__ = ["compress", "decompress", "compressed_bytes",
           "quantize_rows_ref", "dequantize_rows_ref"]
