"""Activation int8 compression Pallas TPU kernels (paper §5.2).

TL's wire traffic is first-layer activations + first/last-layer gradients;
the paper proposes compressing them.  These kernels perform per-row absmax
int8 quantization (and dequantization) so a (tokens, d_model) activation
block ships over ICI/DCN at ~4× fewer bytes + one f32 scale per row.

Grid: row blocks.  BlockSpec tile (BR, D) f32 in, (BR, D) int8 + (BR,) f32
out — e.g. BR=256, D=8192 → 8 MB in-tile, within VMEM for one buffer; use
BR=128 for d_model=8192 models to leave double-buffer headroom.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(x_ref.dtype)


def quantize_rows(x, *, block_rows: int = 128, interpret=None):
    """x: (R, D) -> (int8 (R, D), scales f32 (R,)). R % block_rows == 0."""
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    R, D = x.shape
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), jnp.int8),
            jax.ShapeDtypeStruct((R,), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_rows(q, scales, *, out_dtype=jnp.float32,
                    block_rows: int = 128, interpret=None):
    """Inverse of :func:`quantize_rows`."""
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    R, D = q.shape
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), out_dtype),
        interpret=interpret,
    )(q, scales)
