"""Activation compression Pallas TPU kernels (paper §5.2).

TL's wire traffic is first-layer activations + first/last-layer gradients;
the paper proposes compressing them.  These kernels perform per-row absmax
quantization (and dequantization) at two rungs — int8 and fp8 (e4m3) — so
a (tokens, d_model) activation block ships over ICI/DCN at ~4× fewer bytes
plus one f32 scale per row.

Quantizer formulation (shared by both rungs, and load-bearing for the
error-feedback lane in ``repro.core.transport``):

    scale = max(absmax(row), eps)
    q     = round(x / scale * DENOM)        # int8: clip to ±127; fp8: cast
    x'    = q / DENOM * scale

i.e. the *scale is the raw absmax* and DENOM (127 / 256) divides at
dequant time.  A spatially-constant row then round-trips **bit-exactly**:
``x/scale = ±1.0`` and ``q/DENOM = ±1.0`` are exact float ops, so
``x' == x`` and the error-feedback residual of a constant tensor is
*exactly zero* — the lossless-in-the-limit property the transport's EF
accumulator tests pin.  (The historical ``scale = absmax/127`` form fails
this: ``fl(127 · fl(c/127)) != c`` in general.)

Grid: row blocks.  BlockSpec tile (BR, D) f32 in, (BR, D) int8|fp8 +
(BR,) f32 out — e.g. BR=256, D=8192 → 8 MB in-tile, within VMEM for one
buffer; use BR=128 for d_model=8192 models to leave double-buffer headroom.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# codec -> (wire dtype, dequant denominator).  Exactness at the rails
# (q = ±DENOM → ±1.0) is enforced by ``_pin_rails`` in the dequant — XLA
# may rewrite division by a constant into multiplication by its rounded
# reciprocal or reassociate ``q/DENOM*scale``, either of which is an ulp
# off at the rails.  fp8 uses e4m3fn with a power-of-two denominator on
# top of that: 256 <= 448 (e4m3 max normal) so there is no overflow,
# ±256 is exactly representable, q/256 is exact under *any* rewrite, and
# e4m3's ~2^-4 relative precision is unchanged by which slice of the
# exponent range we use.  int8 keeps the conventional 127 (the
# absmax/127 error bound is pinned by tests).
CODECS = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 256.0),
}


def _check_codec(codec: str):
    if codec not in CODECS:
        raise ValueError(f"unknown wire codec {codec!r}; "
                         f"one of {sorted(CODECS)}")
    return CODECS[codec]


def _pin_rails(qf, u, denom):
    """Force the rail levels ``q == ±DENOM`` to dequantize to exactly
    ``±1.0``.  XLA is free to rewrite ``q / DENOM * scale`` into
    ``q · fl(1/DENOM) · scale`` or ``q · (scale/DENOM)``, either of which
    is off by an ulp at the rails — and the rails are exactly where the
    error-feedback exactness argument lives (a constant row quantizes to
    all-rails and must round-trip bit-equal, so its residual is exactly
    zero).  Interior levels only need the bounded-error property, which
    any rewrite preserves."""
    return jnp.where(jnp.abs(qf) == denom, jnp.sign(qf), u)


def _make_quant_kernel(codec: str):
    qdtype, denom = _check_codec(codec)

    def _quant_kernel(x_ref, q_ref, s_ref):
        x = x_ref[...].astype(jnp.float32)
        absmax = jnp.max(jnp.abs(x), axis=-1)
        scale = jnp.maximum(absmax, 1e-12)
        u = x / scale[:, None] * denom
        if codec == "int8":
            q = jnp.clip(jnp.round(u), -127, 127).astype(qdtype)
        else:
            # e4m3 cast rounds to nearest; |u| <= 256 < 448 max normal
            q = u.astype(qdtype)
        q_ref[...] = q
        s_ref[...] = scale

    return _quant_kernel


def _make_dequant_kernel(codec: str):
    _, denom = _check_codec(codec)

    def _dequant_kernel(q_ref, s_ref, x_ref):
        qf = q_ref[...].astype(jnp.float32)
        u = _pin_rails(qf, qf / denom, denom)
        x_ref[...] = (u * s_ref[...][:, None]).astype(x_ref.dtype)

    return _dequant_kernel


def quantize_rows(x, *, codec: str = "int8", block_rows: int = 128,
                  interpret=None):
    """x: (R, D) -> (int8|fp8 (R, D), scales f32 (R,)). R % block_rows == 0."""
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    qdtype, _ = _check_codec(codec)
    R, D = x.shape
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _make_quant_kernel(codec),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), qdtype),
            jax.ShapeDtypeStruct((R,), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_rows(q, scales, *, codec: str = "int8", out_dtype=jnp.float32,
                    block_rows: int = 128, interpret=None):
    """Inverse of :func:`quantize_rows` (same ``codec``)."""
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    _check_codec(codec)
    R, D = q.shape
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _make_dequant_kernel(codec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), out_dtype),
        interpret=interpret,
    )(q, scales)
