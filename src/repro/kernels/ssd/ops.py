"""Jit'd wrapper for the SSD kernel over model-layout tensors."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.ssd.kernel import ssd_bh


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd(x, dt, A_log, Bm, Cm, *, chunk: int, interpret: bool):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = (dt.astype(jnp.float32) * A).transpose(0, 2, 1).reshape(B * H, S)
    xdt = (x.astype(jnp.float32) * dt[..., None]).transpose(0, 2, 1, 3)
    xf = xdt.reshape(B * H, S, P)
    Bf = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cf = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    y, hT = ssd_bh(dA, xf, Bf, Cf, chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3).astype(x.dtype)
    return y, hT.reshape(B, H, P, N)


def ssd(x, dt, A_log, Bm, Cm, *, chunk: int = 256, interpret=None):
    """Model layout: x (B,S,H,P), dt (B,S,H), A_log (H,), Bm/Cm (B,S,N).

    Returns y (B,S,H,P) and final state (B,H,P,N).  B/C are shared across
    heads (Mamba-2 ngroups=1) and broadcast here.  ``interpret`` resolves
    via ``REPRO_PALLAS_INTERPRET`` (``repro.kernels.resolve_interpret``).
    """
    return _ssd(x, dt, A_log, Bm, Cm, chunk=chunk,
                interpret=resolve_interpret(interpret))
