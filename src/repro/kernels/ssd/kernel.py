"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

Grid: (batch*heads, num_chunks) — chunks are the innermost sequential axis,
so the (P, N) inter-chunk state lives in VMEM scratch and is carried across
chunk iterations (the TPU grid executes minor-most last, in order).

Per chunk the kernel computes the SSD dual form:
  intra-chunk:  y  = ((C·Bᵀ) ⊙ decay(t,s)) · (x·dt)      (chunk-local "attention")
  inter-chunk:  y += (C · h_prev) ⊙ decay(t,start)
  state update: h  = decay(chunk) * h_prev + Σ_s decay(end,s) (x·dt)_s ⊗ B_s

VMEM working set per step (chunk=CK, state N, head_dim P, f32):
  x (CK,P) + B,C (CK,N) + state (P,N) + decay (CK,CK)
  = 256*64 + 2*256*128 + 64*128 + 256*256  floats ≈ 0.7 MB — fits VMEM
with hardware-aligned MXU dims (CK, N, P multiples of 64/128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(dA_ref, x_ref, b_ref, c_ref, y_ref, hT_ref, h_scr, *,
                chunk: int):
    cb = pl.program_id(1)
    ncb = pl.num_programs(1)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dA = dA_ref[0].astype(jnp.float32)          # (CK,)   per-step log decay
    x = x_ref[0].astype(jnp.float32)            # (CK, P) dt-scaled input
    Bm = b_ref[0].astype(jnp.float32)           # (CK, N)
    Cm = c_ref[0].astype(jnp.float32)           # (CK, N)

    seg = jnp.cumsum(dA)                        # (CK,)
    # intra-chunk decay matrix decay(t,s) = exp(seg_t - seg_s) for s <= t
    # (mask before exp: masked entries would overflow)
    rel = seg[:, None] - seg[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    decay = jnp.exp(jnp.where(tri, rel, -1e9))

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of carried state
    h_prev = h_scr[...]                         # (P, N)
    decay_in = jnp.exp(seg)[:, None]            # (CK, 1)
    y += decay_in * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h = exp(seg_end) * h_prev + sum_s exp(seg_end - seg_s) x_s B_s^T
    decay_end = jnp.exp(seg[-1] - seg)[:, None] # (CK, 1)
    xw = x * decay_end
    h_scr[...] = jnp.exp(seg[-1]) * h_prev + jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(cb == ncb - 1)
    def _emit_state():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def ssd_bh(dA, x, Bm, Cm, *, chunk: int = 256, interpret=None):
    """Flattened (batch*heads)-major SSD scan.

    dA: (BH, S) log-decay per step; x: (BH, S, P) dt-scaled inputs;
    Bm, Cm: (BH, S, N).  S must divide by ``chunk``.
    Returns y (BH, S, P) and final state (BH, P, N).
    """
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    BH, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    grid = (BH, S // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[_scratch((P, N), jnp.float32)],
        interpret=interpret,
    )(dA, x, Bm, Cm)
    return y, hT


def _scratch(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.VMEM(shape, dtype)
