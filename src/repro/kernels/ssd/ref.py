"""Pure-jnp oracle for the SSD kernel: naive sequential state-space scan."""
import jax
import jax.numpy as jnp


def ssd_ref_bh(dA, x, Bm, Cm):
    """dA: (BH, S); x: (BH, S, P); Bm, Cm: (BH, S, N).

    Sequential recurrence h_t = exp(dA_t) h_{t-1} + x_t B_t^T; y_t = C_t h_t.
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        da, xt, bt, ct = inp
        h = jnp.exp(da)[:, None, None] * h + jnp.einsum("bp,bn->bpn", xt, bt)
        y = jnp.einsum("bpn,bn->bp", h, ct)
        return h, y

    h0 = jnp.zeros((BH, P, N), jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0,
        (dA.T.astype(jnp.float32), x.transpose(1, 0, 2).astype(jnp.float32),
         Bm.transpose(1, 0, 2).astype(jnp.float32),
         Cm.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2).astype(x.dtype), hT
