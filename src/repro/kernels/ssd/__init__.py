from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref_bh

__all__ = ["ssd", "ssd_ref_bh"]
