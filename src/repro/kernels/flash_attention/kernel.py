"""Blockwise (flash) attention Pallas TPU kernel.

Grid: (batch*q_heads, num_q_blocks, num_kv_blocks) — the KV axis is the
innermost (sequential on TPU) dimension, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and is carried across KV iterations.

BlockSpec tiling keeps the working set in VMEM:
    q tile   (1, BQ, D)        ~ BQ*D*4        bytes
    k/v tile (1, BK, D)        ~ BK*D*4        bytes
    acc      (BQ, D) f32 scratch
with BQ=BK=128 and D<=256 this is ≲0.5 MB — far under the ~16 MB v5e VMEM,
leaving headroom for double buffering.  MXU dims (BQ, D, BK) are multiples
of 128 when D is.

Supports causal masking and sliding-window attention via position offsets.
GQA is handled by the wrapper (ops.py) mapping q-heads onto kv-heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, q_offset: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                    # (BK, D)
    v = v_ref[0].astype(jnp.float32)                    # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, scale: float, causal: bool = True,
                       window: int = 0, q_offset: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret=None):
    """q: (BH, Sq, D); k, v: (BH, Sk, D) — batch*heads pre-flattened.

    Sq/Sk must be divisible by block sizes (the wrapper pads).
    """
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    grid = (BH, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            _scratch((block_q,), jnp.float32),
            _scratch((block_q,), jnp.float32),
            _scratch((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape, dtype):
    from jax.experimental import pallas as pl  # local alias
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - fallback for CPU interpret mode
        return pl.VMEM(shape, dtype)
