"""Pure-jnp oracle for the flash attention kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: int = 0, q_offset: int = 0):
    """q: (BH, Sq, D); k, v: (BH, Sk, D).  Naive materialized attention."""
    Sq, Sk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
