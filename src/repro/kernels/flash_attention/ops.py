"""Jit'd public wrapper: GQA-aware flash attention over (B, S, H, D) layouts."""
import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_bh


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def _flash_attention(q, k, v, *, causal: bool, window: int, block_q: int,
                     block_k: int, interpret: bool):
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    qf, _ = _pad_to(qf, 1, block_q)
    kf, _ = _pad_to(kf, 1, block_k)
    vf, _ = _pad_to(vf, 1, block_k)
    scale = 1.0 / math.sqrt(D)
    out = flash_attention_bh(qf, kf, vf, scale=scale, causal=causal,
                             window=window, block_q=block_q, block_k=block_k,
                             interpret=interpret)
    out = out[:, :Sq].reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128, interpret=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0.

    Returns (B, Sq, H, D).  Pads sequence dims to the block size; padded KV
    positions sit *after* the valid ones and are masked out by the causal
    check as long as Sq == Sk (self-attention), which is the supported case.
    ``interpret`` resolves through ``repro.kernels.resolve_interpret``
    (``REPRO_PALLAS_INTERPRET``) before the jit boundary.
    """
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=resolve_interpret(interpret))
