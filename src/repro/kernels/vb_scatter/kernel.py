"""Virtual-batch reassembly Pallas TPU kernel (the eq. 4–5 prologue).

The TL orchestrator reassembles the virtual batch from concatenated node
payloads: ``out[perm[i]] = payload[i]`` where ``perm`` is the concatenated
``batch_positions`` (a permutation of ``0..N-1``).  XLA lowers each
``zeros_like(x).at[perm].set(x)`` to a *generic* scatter: it materializes
the zero-initialized destination buffer and then updates every row — two
full HBM writes of the reassembled X^(1) per tensor, issued once per
payload tensor (x1, δ^(L), ∂L/∂X^(1)), before the tail vjp reads X^(1)
back.  Because ``perm`` is a permutation, the zeros are dead: every
destination row is written exactly once.

This kernel streams each row exactly once instead.  ``perm`` is
scalar-prefetched (``PrefetchScalarGridSpec``) so BlockSpec index maps can
depend on it; the grid is ``(N, n_col_blocks)`` and grid step ``(i, j)``
DMAs row ``i`` column-block ``j`` of every payload straight to its
destination row — no zeros materialization, no full-batch VMEM residency,
no scatter or sort ops in the lowering.  Two row routings share one body:

* ``scatter``: read row ``i``, write row ``perm[i]`` (the reassembly);
* ``gather``:  read row ``idx[i]``, write row ``i`` (the reassembly's
  transpose — the custom-vjp backward gathers cotangents with the *same*
  ``perm``, no inverse permutation ever materializes).

All payload tensors ride the same grid as a multi-ref call, so the whole
reassembly is one kernel launch and one HBM pass over the payloads.

Tiling (v5e): blocks are ``(1, BLOCK_COLS)`` — VMEM holds
``n_refs × 2 (in+out) × 2 (double-buffer) × BLOCK_COLS × 4 B`` ≈ 0.5 MB at
the default 8192 columns, far under the 16 MB/core budget.  A tensor
narrower than the widest ref collapses to fewer column blocks; its index
map clamps ``j`` so the extra grid steps rewrite the last block
idempotently (only hit when refs of very different widths share a call —
the (N, C) δ^(L) next to a wide (N, D) X^(1)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

BLOCK_COLS = 8192


def _copy_rows_kernel(idx_ref, *refs):
    # refs = (in_0..in_{n-1}, out_0..out_{n-1}); the row routing lives
    # entirely in the BlockSpec index maps, so the body is a plain copy
    del idx_ref
    n = len(refs) // 2
    for in_ref, out_ref in zip(refs[:n], refs[n:]):
        out_ref[...] = in_ref[...]


def permute_rows(idx, *tensors, mode: str = "scatter",
                 block_cols: int = BLOCK_COLS, interpret=None):
    """Route rows of every (N, D_t) tensor by ``idx`` in one fused pass.

    ``mode="scatter"``: ``out_t[idx[i]] = t[i]`` (``idx`` must be a
    permutation of ``0..N-1`` — each destination row is written exactly
    once).  ``mode="gather"``: ``out_t[i] = t[idx[i]]``.  The two modes are
    transposes of each other under the same ``idx``, which is exactly the
    scatter-by-permutation vjp pair.  Dtypes are per-ref (f32/bf16
    activations and int32 token rows mix freely).
    """
    interpret = resolve_interpret(interpret)
    n_rows = tensors[0].shape[0]
    n_blocks = [-(-t.shape[1] // block_cols) for t in tensors]
    grid_cols = max(n_blocks)

    routed = lambda i, idx_ref: idx_ref[i]
    direct = lambda i, idx_ref: i
    in_row, out_row = ((direct, routed) if mode == "scatter"
                       else (routed, direct))

    def specs(row_of):
        out = []
        for t, nb in zip(tensors, n_blocks):
            width = min(t.shape[1], block_cols)

            def index_map(i, j, idx_ref, nb=nb, row_of=row_of):
                return row_of(i, idx_ref), jnp.minimum(j, nb - 1)

            out.append(pl.BlockSpec((1, width), index_map))
        return out

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rows, grid_cols),
        in_specs=specs(in_row),
        out_specs=specs(out_row),
    )
    return pl.pallas_call(
        _copy_rows_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(t.shape, t.dtype) for t in tensors],
        interpret=interpret,
    )(idx, *tensors)


def take_rows(idx, *tensors, block_cols: int = BLOCK_COLS, interpret=None):
    """``out_t[i] = t[idx[i]]`` — :func:`permute_rows` in gather mode."""
    return permute_rows(idx, *tensors, mode="gather", block_cols=block_cols,
                        interpret=interpret)
