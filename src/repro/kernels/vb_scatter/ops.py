"""Jit'd public wrappers: differentiable virtual-batch reassembly.

``scatter_rows(perm, tensors)`` places row ``i`` of every tensor at row
``perm[i]`` of its output in one fused Pallas pass (see ``kernel.py``),
wrapped in a ``jax.custom_vjp`` whose backward is the inverse gather
``d_in[i] = d_out[perm[i]]`` — the exact transpose of a
scatter-by-permutation.  The production TL loss differentiates *through*
the reassembly of X^(1), and the custom rule keeps that backward on the
same single-pass kernel instead of falling back to XLA's generic
scatter/gather lowering.

``vb_scatter(x1, dL, dx1, perm)`` is the orchestrator-payload spelling:
the centralized-BP step's three reassembly scatters as one kernel launch.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels import resolve_interpret
from repro.kernels.vb_scatter.kernel import permute_rows, take_rows


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _scatter_flat(interpret: bool, perm, flats):
    # the kernel scatters by the prefetched perm directly (write row
    # perm[i], read row i) — no inverse permutation, no argsort, and no
    # scatter op anywhere in the compiled step
    return tuple(permute_rows(perm, *flats, mode="scatter",
                              interpret=interpret))


def _scatter_flat_fwd(interpret, perm, flats):
    return _scatter_flat(interpret, perm, flats), perm


def _scatter_flat_bwd(interpret, perm, g):
    # transpose of scatter-by-permutation: gather, d_in[i] = d_out[perm[i]].
    # Integer rows (tokens/targets riding the same fused pass) have float0
    # cotangents — pass them through untouched, gather only the float ones.
    float_pos = [k for k, gk in enumerate(g)
                 if gk.dtype != jax.dtypes.float0]
    gathered = iter(take_rows(perm, *(g[k] for k in float_pos),
                              interpret=interpret) if float_pos else ())
    d_flats = tuple(next(gathered) if k in float_pos else g[k]
                    for k in range(len(g)))
    return np.zeros(perm.shape, dtype=jax.dtypes.float0), d_flats


_scatter_flat.defvjp(_scatter_flat_fwd, _scatter_flat_bwd)


def scatter_rows(perm, tensors, *, interpret=None):
    """``out_t[perm[i]] = t[i]`` for every (N, ...) tensor, one HBM pass.

    ``perm``: int32 (N,) permutation of ``0..N-1`` (the virtual batch's
    concatenated ``batch_positions``).  Tensors may have any trailing shape
    and mixed dtypes; each is flattened to rows for the kernel and restored.
    Differentiable: the custom vjp gathers cotangent rows back by ``perm``.
    """
    tensors = tuple(tensors)
    flats = tuple(t.reshape(t.shape[0], -1) for t in tensors)
    outs = _scatter_flat(resolve_interpret(interpret), perm, flats)
    return tuple(o.reshape(t.shape) for o, t in zip(outs, tensors))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _vb_scatter(x1_cat, dL_cat, dx1_cat, perm, *, interpret: bool):
    return scatter_rows(perm, (x1_cat, dL_cat, dx1_cat), interpret=interpret)


def vb_scatter(x1_cat, dL_cat, dx1_cat, perm, *, interpret=None):
    """Reassemble the TL virtual batch in global shuffled order.

    One fused kernel for the centralized-BP prologue: scatters the
    concatenated node payloads X^(1), δ^(L), ∂L/∂X^(1) by ``perm`` in a
    single pass.  Returns ``(x1, delta_L, dx1)`` in batch order.
    """
    return _vb_scatter(x1_cat, dL_cat, dx1_cat, perm,
                       interpret=resolve_interpret(interpret))
