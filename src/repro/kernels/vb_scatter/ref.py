"""Pure-jnp oracle for virtual-batch reassembly (the seed's scatter path)."""
import jax.numpy as jnp


def scatter_rows_ref(perm, tensors):
    """``out_t[perm[i]] = t[i]`` via XLA's generic ``.at[].set`` scatter."""
    return tuple(jnp.zeros_like(t).at[perm].set(t) for t in tensors)


def vb_scatter_ref(x1_cat, dL_cat, dx1_cat, perm):
    return scatter_rows_ref(perm, (x1_cat, dL_cat, dx1_cat))
