from repro.kernels.vb_scatter.kernel import permute_rows, take_rows
from repro.kernels.vb_scatter.ops import scatter_rows, vb_scatter
from repro.kernels.vb_scatter.ref import scatter_rows_ref, vb_scatter_ref

__all__ = ["permute_rows", "take_rows", "scatter_rows", "vb_scatter",
           "scatter_rows_ref", "vb_scatter_ref"]
