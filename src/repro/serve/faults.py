"""Seeded fault injection + recovery reporting for the serving engine.

The training side already has two hardened layers: the WAN traversal wire
(``repro.core.faults``, PR 5) and the production mesh
(``repro.launch.elastic``, PR 6).  Serving is the third production path,
with its own failure mode: one decode step hangs or crashes and every
in-flight request stalls behind it.  This module supplies the same
counter-based, **order-independent** fault machinery for the serving
engine:

* :class:`ServeFaultSpec` / :class:`ServeFaultInjector` — seeded
  per-``(step, kind)`` verdicts: a decode-step *crash* (the dispatch
  raises, like a real XLA device error) or *hang* (the dispatch never
  completes — detectable only by the watchdog deadline,
  ``repro.core.watchdog``).  ``decide(step)`` is a pure function of
  ``(seed, step)``: the verdict never depends on how many other steps were
  consulted first, so a supervised run that rebuilds and continues
  re-draws identical faults.  Scripted drills (``hang:STEP`` /
  ``crash:STEP``) win over the seeded draw — the deterministic CI
  ``serve-chaos`` drill rides them.
* :class:`ServeFault` — the one exception the engine's supervision loop
  catches: detection (crash, or watchdog-classified hang) normalized to
  ``(step, cause)``.  Without supervision it propagates with a full
  engine-state dump so a wedged run is debuggable from the log alone.
* :class:`ServeRecoveryReport` — the per-recovery cost breakdown
  (detect / rebuild / re-prefill / time-to-next-token) that backs the
  ``recovery`` benchmark column in ``BENCH_serve.json``.

Faults are injected *at the host boundary* (the verdict is consulted as
each decode step is dispatched) because a CPU test host cannot actually
wedge an XLA device; on real hardware the same :class:`ServeFault` is
raised from the runtime's device error or the watchdog, and everything
downstream — rebuild from host-side truth, re-prefill, token-identity —
is identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

HANG = "hang"      # the decode dispatch never completes: only a deadline
CRASH = "crash"    # the decode dispatch raises immediately


class ServeFault(RuntimeError):
    """A decode step was lost at ``step`` (crash, or watchdog-classified
    hang).  With supervision the engine rebuilds from host-side truth and
    continues; without it this propagates as the loud failure."""

    def __init__(self, step: int, cause: str, detail: str = ""):
        msg = (f"decode step {step} lost ({cause}): the engine must be "
               "rebuilt from host-side truth (re-prefill survivors)")
        if detail:
            msg += "\n" + detail
        super().__init__(msg)
        self.step = int(step)
        self.cause = cause


@dataclass(frozen=True)
class ServeDrill:
    """One scripted fault: ``kind`` at decode step ``step``."""

    kind: str                    # HANG | CRASH
    step: int

    def __post_init__(self):
        if self.kind not in (HANG, CRASH):
            raise ValueError(f"unknown serve drill kind: {self.kind!r}")
        if self.step < 0:
            raise ValueError("drill step must be >= 0")


def parse_chaos(text: str) -> Tuple[ServeDrill, ...]:
    """CLI chaos syntax: ``hang:STEP`` / ``crash:STEP``, comma-separated
    for multiple drills (``hang:3,crash:6``)."""
    drills = []
    for part in text.split(","):
        bits = part.strip().split(":")
        if len(bits) != 2 or bits[0] not in (HANG, CRASH):
            raise ValueError(
                f"bad chaos drill {part!r}: expected hang:STEP or "
                "crash:STEP (comma-separated for several)")
        try:
            step = int(bits[1])
        except ValueError:
            raise ValueError(f"bad chaos drill {part!r}: STEP must be an "
                             "integer")
        drills.append(ServeDrill(bits[0], step))
    return tuple(drills)


@dataclass(frozen=True)
class ServeFaultSpec:
    """Seeded decode-fault distribution + scripted drills.

    Probabilities are per decode step: each step draws its own verdict
    from a counter-based RNG keyed ``(seed, step)``, so the verdict never
    depends on consultation order — a rebuilt/continued run re-draws
    identical faults (the invariant ``tests/test_serve.py`` pins,
    mirroring ``core.faults`` and ``launch.elastic``)."""

    crash_prob: float = 0.0
    hang_prob: float = 0.0
    seed: int = 0
    drills: Tuple[ServeDrill, ...] = ()

    def __post_init__(self):
        if not 0.0 <= self.crash_prob < 1.0:
            raise ValueError("crash_prob must be in [0, 1)")
        if not 0.0 <= self.hang_prob < 1.0:
            raise ValueError("hang_prob must be in [0, 1)")
        if self.crash_prob + self.hang_prob >= 1.0:
            raise ValueError("crash_prob + hang_prob must be < 1")


class ServeFaultInjector:
    """Order-independent seeded decode-fault verdicts (see the spec)."""

    def __init__(self, spec: ServeFaultSpec):
        self.spec = spec

    def decide(self, step: int) -> Optional[str]:
        s = self.spec
        for d in s.drills:
            if d.step == step:
                return d.kind
        if s.crash_prob == 0.0 and s.hang_prob == 0.0:
            return None
        u = float(np.random.default_rng((s.seed, int(step))).random())
        if u < s.crash_prob:
            return CRASH
        if u < s.crash_prob + s.hang_prob:
            return HANG
        return None


@dataclass
class ServeRecoveryReport:
    """Cost breakdown of one detect → rebuild → re-prefill recovery."""

    step: int                    # the engine step the fault hit
    cause: str                   # HANG | CRASH
    n_survivors: int = 0         # in-flight sequences re-prefilled
    detect_s: float = 0.0        # dispatch -> ServeFault classified
    rebuild_s: float = 0.0       # fresh pools + allocator from host truth
    reprefill_s: float = 0.0     # survivor re-prefill through block tables
    first_token_s: float = 0.0   # fault -> next token emitted
    extra: dict = field(default_factory=dict)

    @property
    def total_s(self) -> float:
        return self.detect_s + self.rebuild_s + self.reprefill_s

    def as_dict(self) -> dict:
        return {
            "step": self.step, "cause": self.cause,
            "n_survivors": self.n_survivors,
            "detect_s": round(self.detect_s, 4),
            "rebuild_s": round(self.rebuild_s, 4),
            "reprefill_s": round(self.reprefill_s, 4),
            "first_token_s": round(self.first_token_s, 4),
            "total_s": round(self.total_s, 4),
        }
