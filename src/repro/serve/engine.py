"""Continuous-batching scheduler over the paged-KV runner.

One :class:`ServeEngine` owns the page pools, a :class:`PageAllocator`, an
admission queue, and the active slot list.  Each :meth:`step` interleaves:

* **deadline sweep** — in-flight sequences past their SLO deadline are
  aborted (partial results flagged ``partial=True``); queued requests past
  deadline are shed.  Shed/aborted requests always land in ``results``
  with an explicit ``finish_reason`` — never silently dropped.
* **admission** — pop queued requests while a slot is free and the pool
  can *guarantee* the request to completion (pages for prompt +
  max_new_tokens are reserved up front; only the prompt's pages are
  allocated eagerly, the rest lazily at page boundaries — reservation
  means admission can never deadlock mid-decode).  Overload control rides
  admission: a request whose SLO is *provably* unmeetable (queue delay +
  ``max_new_tokens`` × the rolling decode-step clock overshoots its
  deadline) is shed instead of admitted; a small request may bypass a
  head-of-line-blocked giant (bounded by ``hol_bypass`` skips so the
  giant is never starved); a high-priority request may preempt
  lower-priority in-flight sequences for pages/slots.  Preempted
  sequences restore before new traffic of equal priority.  A
  ``decode_priority`` knob throttles prefills: at priority k, at most one
  admission per k decode steps while traffic is active.
* **decode** — one batched decode step for all active sequences.  The
  batch is padded to the next power-of-two bucket (bounding jit
  retraces); padded rows point every block-table slot at the trash page
  with length 0, and row independence (see ``runner``) makes them inert.
  With a :class:`ServeFaultSpec` armed, the dispatch consults the seeded
  injector and runs under the ``repro.core.watchdog`` deadline; a lost
  step (crash, or watchdog-classified hang) triggers supervised recovery:
  rebuild pools + allocator from host-side truth and re-prefill every
  survivor — no token was emitted for the lost step, so completed
  requests stay bit-identical to the fault-free run.
* **eviction + compaction** — sequences finishing on EOS or
  max_new_tokens free their pages and leave; the active list is rebuilt
  dense (order preserved), so the decode batch never carries holes.

**KV preemption/restore**: ``preempt(rid)`` (or the scheduler, on
priority inversion / an ``OutOfPages`` burst in overcommit mode) evicts a
sequence's pages and stashes its prompt + generated tokens host-side; the
restore path re-prefills the stashed prefix through the existing
block-table scatter and resumes decoding at the same RNG stream position.
Token-identical by construction: sampling folds in ``(seed, step)``, never
batch composition, and the re-prefilled prefix is exactly the token
sequence the oracle would have cached.

Token streams are deterministic: greedy rows depend only on the model, and
sampled rows use per-request RNG streams (``repro.serve.sampling``) that
depend only on (engine base seed, request seed, tokens generated), never on
co-batched traffic.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.watchdog import WatchdogTimeout, call_with_deadline, \
    simulate_hang
from repro.serve import runner
from repro.serve.allocator import OutOfPages, PageAllocator
from repro.serve.faults import (CRASH, HANG, ServeFault, ServeFaultInjector,
                                ServeFaultSpec, ServeRecoveryReport)
from repro.serve.sampling import request_key, sample_tokens


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32, P >= 1
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    eos_id: int | None = None
    arrival: float = 0.0                # wall-clock submit time (bench)
    deadline: float | None = None       # absolute engine-clock SLO, or None
    priority: int = 0                   # higher admits first, may preempt


@dataclass
class RequestResult:
    rid: int
    tokens: list[int] = field(default_factory=list)
    arrival: float = 0.0
    admitted: float = 0.0
    token_times: list[float] = field(default_factory=list)
    prompt_len: int = 0
    finish_reason: str = ""             # "eos"|"length"|"shed"|"deadline"
    partial: bool = False               # aborted past-deadline mid-stream
    preemptions: int = 0                # times the KV cache was evicted


class _Seq:
    __slots__ = ("req", "pages", "length", "n_gen", "last_token", "key",
                 "reserve_left", "result", "started_step")

    def __init__(self, req, pages, key, reserve_left, result, started_step):
        self.req = req
        self.pages = pages              # allocated page ids, in order
        self.length = len(req.prompt)   # tokens currently in the KV cache
        self.n_gen = 0                  # tokens emitted so far
        self.last_token = -1
        self.key = key                  # per-request RNG root (2,) uint32
        self.reserve_left = reserve_left
        self.result = result
        self.started_step = started_step


def _bucket(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class ServeEngine:
    """Continuous batching + paged KV cache serving engine."""

    def __init__(self, model, cfg, params, *, num_pages: int = 64,
                 page_size: int = 8, max_slots: int = 8, max_len: int = 128,
                 attention: str = "paged", decode_priority: int = 1,
                 seed: int = 0, interpret=None, clock=time.time,
                 faults: ServeFaultSpec | None = None,
                 watchdog_s: float | None = None, supervise: bool = True,
                 shedding: bool = True, hol_bypass: int = 16,
                 overcommit: bool = False):
        runner.check_servable(cfg)
        del model                        # runner drives `cfg` + params directly
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_len = max_len
        self.max_pages_per_seq = -(-max_len // page_size)
        self.max_slots = max_slots
        self.decode_priority = max(0, decode_priority)
        self.attention = attention
        self.clock = clock
        self.alloc = PageAllocator(num_pages, page_size)
        self.pages = runner.init_pages(cfg, num_pages, page_size)
        self._prefill = runner.get_prefill_fn(cfg, page_size=page_size)
        self._decode = runner.get_decode_fn(cfg, page_size=page_size,
                                            attention_impl=attention,
                                            interpret=interpret)
        self._base_key = jax.random.PRNGKey(seed)
        self.pending: deque[Request] = deque()
        self.active: list[_Seq] = []
        self.preempted: list[_Seq] = []  # host-stashed, awaiting restore
        self.results: dict[int, RequestResult] = {}
        self.shed: list[int] = []        # rids shed/aborted past deadline
        self._rids: set[int] = set()     # every rid ever submitted
        self._reserved = 0               # pages promised but not yet allocated
        self._hol_skips: dict[int, int] = {}
        self._steps_since_admit = 10 ** 9
        self.n_steps = 0
        # robustness knobs + counters
        self.supervise = supervise
        self.shedding = shedding
        self.hol_bypass = max(0, hol_bypass)
        self.overcommit = overcommit
        self.watchdog_s = watchdog_s
        self._injector = ServeFaultInjector(faults) if faults else None
        if faults is not None and watchdog_s is None and (
                faults.hang_prob > 0
                or any(d.kind == HANG for d in faults.drills)):
            raise ValueError("hang fault injection needs watchdog_s: a hang "
                             "is detectable only by a deadline")
        self._step_ema: float | None = None   # rolling decode-step seconds
        self._t_step = 0.0
        self.recoveries: list[ServeRecoveryReport] = []
        self._await_first_token: tuple[ServeRecoveryReport, float] | None = None
        self.n_shed = 0
        self.n_deadline_aborts = 0
        self.n_preempted = 0
        self.n_restored = 0
        self.n_rebuilds = 0

    # ------------------------------------------------------------- public API
    def submit(self, req: Request) -> None:
        if req.rid in self._rids:
            raise ValueError(
                f"duplicate rid {req.rid}: a second submit would silently "
                "collide in the results table")
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"max_len={self.max_len}")
        if self.alloc.pages_for(total) > self.alloc.num_pages - 1:
            raise ValueError(f"request {req.rid} can never fit the pool")
        self._rids.add(req.rid)
        self.pending.append(req)

    @property
    def idle(self) -> bool:
        return not self.pending and not self.active and not self.preempted

    def step(self) -> None:
        """One scheduler tick: expire deadlines, maybe admit, then one
        batched decode step (supervised when a fault spec is armed)."""
        self._t_step = t0 = self.clock()
        self._expire(t0)
        self._admit(t0)
        if self.active:
            try:
                self._decode_step()
            except ServeFault as e:
                if not self.supervise:
                    raise ServeFault(e.step, e.cause,
                                     self._dump("engine state at fault:")
                                     ) from e
                self._recover(e)
        self.n_steps += 1
        dt = self.clock() - t0
        self._step_ema = (dt if self._step_ema is None
                          else 0.8 * self._step_ema + 0.2 * dt)

    def run(self, max_steps: int = 1_000_000) -> dict[int, RequestResult]:
        """Drive to completion of everything submitted so far."""
        for _ in range(max_steps):
            if self.idle:
                return self.results
            self.step()
        if self.idle:
            return self.results
        raise RuntimeError(
            self._dump(f"engine not idle after {max_steps} steps:"))

    def serve(self, requests, arrival_steps=None,
              preempt_at=()) -> dict[int, RequestResult]:
        """Deterministic schedule driver: submit ``requests[i]`` when the
        engine reaches step ``arrival_steps[i]`` (default: all at step 0),
        and force-preempt rid at step for every ``(step, rid)`` in
        ``preempt_at``.  Used by the oracle-equivalence tests to pin
        staggered admission and preemption/restore schedules."""
        arrival_steps = list(arrival_steps or [0] * len(requests))
        order = sorted(range(len(requests)), key=lambda i: arrival_steps[i])
        preempt_at = sorted(preempt_at)
        i = 0
        while i < len(order) or not self.idle:
            while i < len(order) and self.n_steps >= arrival_steps[order[i]]:
                self.submit(requests[order[i]])
                i += 1
            if self.idle and i < len(order):
                self.n_steps = arrival_steps[order[i]]   # jump idle gaps
                continue
            for st, rid in preempt_at:
                if st == self.n_steps:
                    self.preempt(rid)
            self.step()
        return self.results

    def preempt(self, rid: int) -> bool:
        """Force-evict an in-flight sequence's KV pages (stashed host-side;
        restored later via re-prefill).  Returns False when ``rid`` is not
        currently decoding."""
        for s in self.active:
            if s.req.rid == rid:
                self._preempt_seq(s)
                return True
        return False

    def stats(self) -> dict:
        """Host-side robustness/overload counters (bench + CLI reporting)."""
        return {
            "n_steps": self.n_steps,
            "n_shed": self.n_shed,
            "n_deadline_aborts": self.n_deadline_aborts,
            "n_preempted": self.n_preempted,
            "n_restored": self.n_restored,
            "n_rebuilds": self.n_rebuilds,
            "shed_rids": sorted(self.shed),
            "step_ema_s": self._step_ema,
        }

    def check_invariants(self) -> None:
        """Page-map safety net: every live page is mapped by exactly one
        active sequence, preempted/pending hold nothing, and the
        reservation ledger balances.  Raises with a state dump."""
        mapped: dict[int, int] = {}
        for s in self.active:
            for p in s.pages:
                mapped[p] = mapped.get(p, 0) + 1
        double = sorted(p for p, n in mapped.items() if n > 1)
        problems = []
        if double:
            problems.append(f"double-mapped pages {double}")
        if set(mapped) != set(self.alloc._refs):
            problems.append(
                f"page map != allocator ledger: mapped={sorted(mapped)} "
                f"allocated={sorted(self.alloc._refs)}")
        if (self.alloc.free_pages + self.alloc.live_pages
                != self.alloc.num_pages - 1):
            problems.append("free list not conserved")
        if any(s.pages for s in self.preempted):
            problems.append("preempted sequence still holds pages")
        if self._reserved != sum(s.reserve_left for s in self.active):
            problems.append(
                f"reservation ledger off: {self._reserved} != "
                f"{sum(s.reserve_left for s in self.active)}")
        if problems:
            raise RuntimeError(
                self._dump("engine invariant violation: "
                           + "; ".join(problems)))

    # ---------------------------------------------------------- diagnostics
    def _dump(self, head: str) -> str:
        act = [f"{s.req.rid}(len={s.length},gen={s.n_gen}/"
               f"{s.req.max_new_tokens},pages={len(s.pages)},"
               f"resv={s.reserve_left},prio={s.req.priority})"
               for s in self.active]
        ema = ("none" if self._step_ema is None
               else f"{self._step_ema:.4f}s")
        return "\n".join([
            head,
            f"  step={self.n_steps} step_ema={ema} "
            f"attention={self.attention}",
            f"  queued  rids={[r.rid for r in self.pending]}",
            f"  active  {act or '[]'}",
            f"  preempted rids="
            f"{[s.req.rid for s in self.preempted]}",
            f"  pages   live={self.alloc.live_pages} "
            f"free={self.alloc.free_pages} "
            f"capacity={self.alloc.num_pages - 1} "
            f"reserved={self._reserved}",
            f"  counters shed={self.n_shed} "
            f"deadline_aborts={self.n_deadline_aborts} "
            f"preempted={self.n_preempted} restored={self.n_restored} "
            f"rebuilds={self.n_rebuilds}",
        ])

    # ------------------------------------------------------ deadline sweeps
    def _finish(self, seq: _Seq, reason: str, partial: bool = False) -> None:
        seq.result.finish_reason = reason
        seq.result.partial = partial
        if seq.pages:
            self.alloc.free(seq.pages)
            seq.pages = []
        self._reserved -= seq.reserve_left
        seq.reserve_left = 0

    def _expire(self, now: float) -> None:
        """Abort in-flight/preempted sequences past their deadline (partial
        results flagged) and shed queued requests past theirs."""
        if not self.shedding:
            return
        for s in list(self.active):
            if s.req.deadline is not None and now > s.req.deadline:
                self._finish(s, "deadline", partial=True)
                self.active.remove(s)
                self.shed.append(s.req.rid)
                self.n_deadline_aborts += 1
        for s in list(self.preempted):
            if s.req.deadline is not None and now > s.req.deadline:
                self._finish(s, "deadline", partial=True)
                self.preempted.remove(s)
                self.shed.append(s.req.rid)
                self.n_deadline_aborts += 1
        for req in list(self.pending):
            if req.deadline is not None and now > req.deadline:
                self._shed(req)

    def _shed(self, req: Request) -> None:
        """Refuse a queued request whose SLO is unmeetable — explicitly:
        it lands in ``results`` as finish_reason="shed", never vanishes."""
        self.pending.remove(req)
        self.results[req.rid] = RequestResult(
            rid=req.rid, arrival=req.arrival, prompt_len=len(req.prompt),
            finish_reason="shed")
        self.shed.append(req.rid)
        self.n_shed += 1

    def _unmeetable(self, req: Request, now: float) -> bool:
        """Provably-missed SLO: even admitted *right now* with zero queue
        delay ahead, ``max_new_tokens`` decode steps at the rolling step
        clock overshoot the deadline.  Conservative by design — no
        estimate, no shed."""
        if req.deadline is None:
            return False
        if now >= req.deadline:
            return True
        if self._step_ema is None:
            return False
        return now + req.max_new_tokens * self._step_ema > req.deadline

    # -------------------------------------------------------------- admission
    def _need_pages(self, prompt_len: int, max_new: int) -> tuple[int, int]:
        """(pages to allocate now, pages to hold in reserve).  Overcommit
        mode reserves nothing — lazy growth may then hit OutOfPages, which
        the decode path survives by preempting a victim."""
        total = self.alloc.pages_for(prompt_len + max_new)
        eager = self.alloc.pages_for(prompt_len)
        return (eager, 0) if self.overcommit else (eager, total - eager)

    def _admit(self, now: float) -> None:
        admitted = 0
        while len(self.active) < self.max_slots or self._has_inversion():
            if self.active and (admitted >= 1 or
                                self._steps_since_admit
                                < self.decode_priority):
                break
            cand = self._pick_candidate(now)
            if cand is None:
                break
            kind, obj = cand
            if kind == "restore":
                self.preempted.remove(obj)
                self._restore_seq(obj, now)
            else:
                self.pending.remove(obj)
                self._hol_skips.pop(obj.rid, None)
                self._start(obj)
            admitted += 1
            self._steps_since_admit = 0
        if admitted == 0:
            self._steps_since_admit += 1

    def _has_inversion(self) -> bool:
        """True when queued/preempted traffic outranks someone in-flight —
        the one case admission may run at full slots (it preempts)."""
        if not self.active:
            return False
        floor = min(s.req.priority for s in self.active)
        return (any(r.priority > floor for r in self.pending)
                or any(s.req.priority > floor for s in self.preempted))

    def _pick_candidate(self, now: float):
        """Next admission: preempted restores and queued requests merged by
        priority (restores first within a priority class, FIFO within
        each), with SLO shedding, head-of-line bypass (bounded by
        ``hol_bypass``), and priority preemption of in-flight victims."""
        entries = ([("restore", s, s.req.priority) for s in self.preempted]
                   + [("start", r, r.priority) for r in self.pending])
        entries.sort(key=lambda e: -e[2])          # stable: FIFO within class
        blocked: list[int] = []
        for kind, obj, prio in entries:
            req = obj.req if kind == "restore" else obj
            if kind == "start" and self.shedding and \
                    self._unmeetable(req, now):
                self._shed(req)
                continue
            if kind == "restore":
                eager = self.alloc.pages_for(obj.length)
                reserve = (0 if self.overcommit else
                           self.alloc.pages_for(
                               len(req.prompt) + req.max_new_tokens) - eager)
            else:
                eager, reserve = self._need_pages(len(req.prompt),
                                                  req.max_new_tokens)
            need = eager + reserve
            slot_ok = len(self.active) < self.max_slots
            pages_ok = need <= self.alloc.free_pages - self._reserved
            if slot_ok and pages_ok:
                for r in blocked:
                    self._hol_skips[r] = self._hol_skips.get(r, 0) + 1
                return kind, obj
            if self._make_room(prio, need, need_slot=not slot_ok):
                for r in blocked:
                    self._hol_skips[r] = self._hol_skips.get(r, 0) + 1
                return kind, obj
            if kind == "start":
                if self._hol_skips.get(req.rid, 0) >= self.hol_bypass:
                    return None      # bypass budget spent: strict FIFO wait
                blocked.append(req.rid)
        return None

    def _make_room(self, prio: int, need: int, need_slot: bool) -> bool:
        """Priority inversion: evict strictly-lower-priority in-flight
        victims (lowest priority first, youngest first within a class —
        cheapest re-prefill) until ``need`` pages and, if required, a slot
        are available.  All-or-nothing: no victim is preempted unless the
        plan succeeds."""
        victims = sorted((s for s in self.active if s.req.priority < prio),
                         key=lambda s: (s.req.priority, -s.started_step))
        chosen: list[_Seq] = []
        gain = 0

        def satisfied():
            pages_ok = (self.alloc.free_pages - self._reserved + gain
                        >= need)
            slot_ok = (not need_slot
                       or len(self.active) - len(chosen) < self.max_slots)
            return pages_ok and slot_ok

        for v in victims:
            if satisfied():
                break
            chosen.append(v)
            gain += len(v.pages) + v.reserve_left
        if not satisfied():
            return False
        for v in chosen:
            self._preempt_seq(v)
        return True

    def _start(self, req: Request) -> None:
        now = self.clock()
        P = len(req.prompt)
        eager, reserve = self._need_pages(P, req.max_new_tokens)
        pages = self.alloc.alloc(eager)
        self._reserved += reserve

        table = np.zeros((self.max_pages_per_seq,), np.int32)
        table[:len(pages)] = pages
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, self.pages = self._prefill(self.params, self.pages, prompt,
                                           jnp.asarray(table))

        result = RequestResult(rid=req.rid, arrival=req.arrival, admitted=now,
                               prompt_len=P)
        key = np.asarray(request_key(self._base_key, req.seed))
        seq = _Seq(req, pages, key, reserve, result, self.n_steps)
        tok = int(np.asarray(sample_tokens(
            logits, jnp.asarray(key)[None],
            jnp.zeros((1,), jnp.int32),
            jnp.full((1,), req.temperature, jnp.float32)))[0])
        self.results[req.rid] = result
        if not self._emit(seq, tok, self.clock()):
            self.active.append(seq)

    # --------------------------------------------------- preemption/restore
    def _preempt_seq(self, seq: _Seq) -> None:
        """Evict a sequence's KV pages; its identity (prompt + emitted
        tokens + RNG stream position) is already host-side, which is all a
        restore needs."""
        self.alloc.free(seq.pages)
        seq.pages = []
        self._reserved -= seq.reserve_left
        seq.reserve_left = 0
        seq.result.preemptions += 1
        self.active.remove(seq)
        self.preempted.append(seq)
        self.n_preempted += 1

    def _restore_seq(self, seq: _Seq, now: float) -> None:
        """Rebuild an evicted sequence's KV by re-prefilling its stashed
        prefix (prompt + all emitted tokens but the pending one) through
        the block-table scatter path.  The prefill logits are discarded —
        the token at that position was already emitted — and decoding
        resumes at RNG stream position ``n_gen``, so the continuation is
        token-identical to a never-preempted run."""
        req = seq.req
        prefix = np.asarray(req.prompt, np.int32)
        if seq.n_gen > 1:
            prefix = np.concatenate(
                [prefix, np.asarray(seq.result.tokens[:seq.n_gen - 1],
                                    np.int32)])
        assert len(prefix) == seq.length, (len(prefix), seq.length)
        eager = self.alloc.pages_for(seq.length)
        reserve = (0 if self.overcommit else
                   self.alloc.pages_for(len(req.prompt) + req.max_new_tokens)
                   - eager)
        seq.pages = self.alloc.alloc(eager)
        seq.reserve_left = reserve
        self._reserved += reserve
        table = np.zeros((self.max_pages_per_seq,), np.int32)
        table[:len(seq.pages)] = seq.pages
        _logits, self.pages = self._prefill(
            self.params, self.pages, jnp.asarray(prefix)[None],
            jnp.asarray(table))
        self.active.append(seq)
        self.n_restored += 1

    def _pick_victim(self, exclude: _Seq) -> _Seq | None:
        cands = [s for s in self.active if s is not exclude]
        if not cands:
            return None
        return min(cands, key=lambda s: (s.req.priority, -s.started_step))

    # ----------------------------------------------------------------- decode
    def _grow_pages(self) -> None:
        """Lazy page growth at boundaries.  Under reservation accounting
        this cannot fail; in overcommit mode an ``OutOfPages`` burst is
        survived by preempting a victim (never the growing sequence)."""
        for s in list(self.active):
            if s not in self.active:     # preempted as a victim below
                continue
            while len(s.pages) * self.page_size <= s.length:
                try:
                    s.pages.extend(self.alloc.alloc(1))
                except OutOfPages:
                    victim = self._pick_victim(exclude=s)
                    if victim is None:
                        raise RuntimeError(self._dump(
                            "OutOfPages with no preemptable victim — the "
                            "pool cannot hold even one sequence:"))
                    self._preempt_seq(victim)
                    continue
                if s.reserve_left > 0:
                    s.reserve_left -= 1
                    self._reserved -= 1

    def _dispatch_decode(self, tokens, lengths, tables):
        """The device call, behind the fault injector and the watchdog.
        A crash verdict raises like a real device error; a hang verdict
        stalls until the watchdog classifies it.  Either way no token is
        emitted for the lost step — recovery re-prefills and the streams
        continue bit-identically."""
        verdict = (self._injector.decide(self.n_steps)
                   if self._injector else None)
        if verdict == CRASH:
            raise ServeFault(self.n_steps, CRASH)

        def call():
            logits, pages = self._decode(
                self.params, self.pages, jnp.asarray(tokens),
                jnp.asarray(lengths), jnp.asarray(tables))
            # sync inside the guarded call so a hang is watchdog-visible
            return np.asarray(logits), pages

        if verdict == HANG:
            work = lambda: simulate_hang(self.watchdog_s)  # noqa: E731
        else:
            work = call
        if self.watchdog_s is not None:
            try:
                return call_with_deadline(
                    work, deadline_s=self.watchdog_s,
                    what=f"decode step {self.n_steps}")
            except WatchdogTimeout as e:
                raise ServeFault(self.n_steps, HANG) from e
        return work()

    def _decode_step(self) -> None:
        self._grow_pages()
        acts = self.active
        if not acts:
            return

        B = len(acts)
        bucket = _bucket(B, self.max_slots)
        tokens = np.zeros((bucket,), np.int32)
        lengths = np.zeros((bucket,), np.int32)
        tables = np.zeros((bucket, self.max_pages_per_seq), np.int32)
        keys = np.zeros((bucket, 2), np.uint32)
        steps = np.zeros((bucket,), np.int32)
        temps = np.zeros((bucket,), np.float32)
        for i, s in enumerate(acts):
            tokens[i] = s.last_token
            lengths[i] = s.length
            tables[i, :len(s.pages)] = s.pages
            keys[i] = s.key
            steps[i] = s.n_gen
            temps[i] = s.req.temperature

        logits, self.pages = self._dispatch_decode(tokens, lengths, tables)
        toks = np.asarray(sample_tokens(jnp.asarray(logits),
                                        jnp.asarray(keys),
                                        jnp.asarray(steps),
                                        jnp.asarray(temps)))
        now = self.clock()
        survivors = []
        for i, s in enumerate(acts):
            s.length += 1                # the fed token's KV is cached now
            if not self._emit(s, int(toks[i]), now):
                survivors.append(s)
        self.active = survivors          # compaction: dense, order-preserving

    # ----------------------------------------------------- fault supervision
    def _recover(self, fault: ServeFault) -> None:
        """Rebuild from host-side truth after a lost decode step: fresh
        page pools + allocator (the device state is gone), then re-prefill
        every in-flight survivor from its stashed tokens.  The lost step
        emitted nothing, so completed requests are bit-identical to the
        fault-free run."""
        t_fault = self.clock()
        report = ServeRecoveryReport(
            step=fault.step, cause=fault.cause,
            n_survivors=len(self.active),
            detect_s=t_fault - self._t_step)
        self.pages = runner.init_pages(self.cfg, self.alloc.num_pages,
                                       self.page_size)
        self.alloc = PageAllocator(self.alloc.num_pages, self.page_size)
        self._reserved = 0
        survivors, self.active = self.active, []
        for s in survivors:
            s.pages = []
            s.reserve_left = 0
        t_rebuilt = self.clock()
        report.rebuild_s = t_rebuilt - t_fault
        for s in survivors:
            # capacity cannot fail: the survivors held exactly these pages
            self._restore_seq(s, t_rebuilt)
            self.n_restored -= 1         # rebuild is not a scheduler restore
        report.reprefill_s = self.clock() - t_rebuilt
        self.recoveries.append(report)
        self._await_first_token = (report, t_fault)
        self.n_rebuilds += 1

    def _emit(self, seq: _Seq, tok: int, now: float) -> bool:
        """Record one generated token; finish (and free) on EOS/len.
        Returns True when the sequence left the engine."""
        if self._await_first_token is not None:
            report, t_fault = self._await_first_token
            report.first_token_s = now - t_fault
            self._await_first_token = None
        seq.n_gen += 1
        seq.last_token = tok
        seq.result.tokens.append(tok)
        seq.result.token_times.append(now)
        done_eos = seq.req.eos_id is not None and tok == seq.req.eos_id
        done_len = seq.n_gen >= seq.req.max_new_tokens
        if done_eos or done_len:
            self._finish(seq, "eos" if done_eos else "length")
            return True
        return False
