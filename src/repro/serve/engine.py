"""Continuous-batching scheduler over the paged-KV runner.

One :class:`ServeEngine` owns the page pools, a :class:`PageAllocator`, an
admission queue, and the active slot list.  Each :meth:`step` interleaves:

* **admission** — pop queued requests while a slot is free and the pool can
  *guarantee* the request to completion (pages for prompt + max_new_tokens
  are reserved up front; only the prompt's pages are allocated eagerly, the
  rest lazily at page boundaries — reservation means admission can never
  deadlock mid-decode).  A ``decode_priority`` knob throttles prefills: at
  priority k, at most one admission per k decode steps while traffic is
  active, keeping per-token latency bounded under bursts.
* **decode** — one batched decode step for all active sequences.  The batch
  is padded to the next power-of-two bucket (bounding jit retraces); padded
  rows point every block-table slot at the trash page with length 0, and
  row independence (see ``runner``) makes them inert.
* **eviction + compaction** — sequences finishing on EOS or max_new_tokens
  free their pages and leave; the active list is rebuilt dense (order
  preserved), so the decode batch never carries holes.

Token streams are deterministic: greedy rows depend only on the model, and
sampled rows use per-request RNG streams (``repro.serve.sampling``) that
depend only on (engine base seed, request seed, tokens generated), never on
co-batched traffic.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import runner
from repro.serve.allocator import PageAllocator
from repro.serve.sampling import request_key, sample_tokens


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32, P >= 1
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    eos_id: int | None = None
    arrival: float = 0.0                # wall-clock submit time (bench)


@dataclass
class RequestResult:
    rid: int
    tokens: list[int] = field(default_factory=list)
    arrival: float = 0.0
    admitted: float = 0.0
    token_times: list[float] = field(default_factory=list)
    prompt_len: int = 0
    finish_reason: str = ""             # "eos" | "length"


class _Seq:
    __slots__ = ("req", "pages", "length", "n_gen", "last_token", "key",
                 "reserve_left", "result")

    def __init__(self, req, pages, key, reserve_left, result):
        self.req = req
        self.pages = pages              # allocated page ids, in order
        self.length = len(req.prompt)   # tokens currently in the KV cache
        self.n_gen = 0                  # tokens emitted so far
        self.last_token = -1
        self.key = key                  # per-request RNG root (2,) uint32
        self.reserve_left = reserve_left
        self.result = result


def _bucket(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class ServeEngine:
    """Continuous batching + paged KV cache serving engine."""

    def __init__(self, model, cfg, params, *, num_pages: int = 64,
                 page_size: int = 8, max_slots: int = 8, max_len: int = 128,
                 attention: str = "paged", decode_priority: int = 1,
                 seed: int = 0, interpret=None, clock=time.time):
        runner.check_servable(cfg)
        del model                        # runner drives `cfg` + params directly
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_len = max_len
        self.max_pages_per_seq = -(-max_len // page_size)
        self.max_slots = max_slots
        self.decode_priority = max(0, decode_priority)
        self.attention = attention
        self.clock = clock
        self.alloc = PageAllocator(num_pages, page_size)
        self.pages = runner.init_pages(cfg, num_pages, page_size)
        self._prefill = runner.get_prefill_fn(cfg, page_size=page_size)
        self._decode = runner.get_decode_fn(cfg, page_size=page_size,
                                            attention_impl=attention,
                                            interpret=interpret)
        self._base_key = jax.random.PRNGKey(seed)
        self.pending: deque[Request] = deque()
        self.active: list[_Seq] = []
        self.results: dict[int, RequestResult] = {}
        self._reserved = 0               # pages promised but not yet allocated
        self._steps_since_admit = 10 ** 9
        self.n_steps = 0

    # ------------------------------------------------------------- public API
    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"max_len={self.max_len}")
        if self.alloc.pages_for(total) > self.alloc.num_pages - 1:
            raise ValueError(f"request {req.rid} can never fit the pool")
        self.pending.append(req)

    @property
    def idle(self) -> bool:
        return not self.pending and not self.active

    def step(self) -> None:
        """One scheduler tick: maybe admit, then one batched decode step."""
        self._admit()
        if self.active:
            self._decode_step()
        self.n_steps += 1

    def run(self, max_steps: int = 1_000_000) -> dict[int, RequestResult]:
        """Drive to completion of everything submitted so far."""
        for _ in range(max_steps):
            if self.idle:
                return self.results
            self.step()
        raise RuntimeError(f"engine not idle after {max_steps} steps")

    def serve(self, requests, arrival_steps=None) -> dict[int, RequestResult]:
        """Deterministic schedule driver: submit ``requests[i]`` when the
        engine reaches step ``arrival_steps[i]`` (default: all at step 0).
        Used by the oracle-equivalence tests to pin staggered admission."""
        arrival_steps = list(arrival_steps or [0] * len(requests))
        order = sorted(range(len(requests)), key=lambda i: arrival_steps[i])
        i = 0
        while i < len(order) or not self.idle:
            while i < len(order) and self.n_steps >= arrival_steps[order[i]]:
                self.submit(requests[order[i]])
                i += 1
            if self.idle and i < len(order):
                self.n_steps = arrival_steps[order[i]]   # jump idle gaps
                continue
            self.step()
        return self.results

    # -------------------------------------------------------------- admission
    def _admit(self) -> None:
        admitted = 0
        while self.pending and len(self.active) < self.max_slots:
            if self.active and (admitted >= 1 or
                                self._steps_since_admit < self.decode_priority):
                break
            req = self.pending[0]
            need = self.alloc.pages_for(len(req.prompt) + req.max_new_tokens)
            if need > self.alloc.free_pages - self._reserved:
                break                    # head-of-line waits for evictions
            self.pending.popleft()
            self._start(req)
            admitted += 1
            self._steps_since_admit = 0
        if admitted == 0:
            self._steps_since_admit += 1

    def _start(self, req: Request) -> None:
        now = self.clock()
        P = len(req.prompt)
        need = self.alloc.pages_for(P + req.max_new_tokens)
        prompt_pages = self.alloc.pages_for(P)
        pages = self.alloc.alloc(prompt_pages)
        self._reserved += need - prompt_pages

        table = np.zeros((self.max_pages_per_seq,), np.int32)
        table[:len(pages)] = pages
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, self.pages = self._prefill(self.params, self.pages, prompt,
                                           jnp.asarray(table))

        result = RequestResult(rid=req.rid, arrival=req.arrival, admitted=now,
                               prompt_len=P)
        key = np.asarray(request_key(self._base_key, req.seed))
        seq = _Seq(req, pages, key, need - prompt_pages, result)
        tok = int(np.asarray(sample_tokens(
            logits, jnp.asarray(key)[None],
            jnp.zeros((1,), jnp.int32),
            jnp.full((1,), req.temperature, jnp.float32)))[0])
        self.results[req.rid] = result
        if not self._emit(seq, tok, self.clock()):
            self.active.append(seq)

    # ----------------------------------------------------------------- decode
    def _decode_step(self) -> None:
        acts = self.active
        for s in acts:                   # lazy page growth at boundaries
            while len(s.pages) * self.page_size <= s.length:
                s.pages.extend(self.alloc.alloc(1))
                s.reserve_left -= 1
                self._reserved -= 1

        B = len(acts)
        bucket = _bucket(B, self.max_slots)
        tokens = np.zeros((bucket,), np.int32)
        lengths = np.zeros((bucket,), np.int32)
        tables = np.zeros((bucket, self.max_pages_per_seq), np.int32)
        keys = np.zeros((bucket, 2), np.uint32)
        steps = np.zeros((bucket,), np.int32)
        temps = np.zeros((bucket,), np.float32)
        for i, s in enumerate(acts):
            tokens[i] = s.last_token
            lengths[i] = s.length
            tables[i, :len(s.pages)] = s.pages
            keys[i] = s.key
            steps[i] = s.n_gen
            temps[i] = s.req.temperature

        logits, self.pages = self._decode(
            self.params, self.pages, jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(tables))
        toks = np.asarray(sample_tokens(logits, jnp.asarray(keys),
                                        jnp.asarray(steps),
                                        jnp.asarray(temps)))
        now = self.clock()
        survivors = []
        for i, s in enumerate(acts):
            s.length += 1                # the fed token's KV is cached now
            if not self._emit(s, int(toks[i]), now):
                survivors.append(s)
        self.active = survivors          # compaction: dense, order-preserving

    def _emit(self, seq: _Seq, tok: int, now: float) -> bool:
        """Record one generated token; finish (and free) on EOS/len.
        Returns True when the sequence left the engine."""
        seq.n_gen += 1
        seq.last_token = tok
        seq.result.tokens.append(tok)
        seq.result.token_times.append(now)
        done_eos = seq.req.eos_id is not None and tok == seq.req.eos_id
        done_len = seq.n_gen >= seq.req.max_new_tokens
        if done_eos or done_len:
            seq.result.finish_reason = "eos" if done_eos else "length"
            self.alloc.free(seq.pages)
            self._reserved -= seq.reserve_left
            seq.reserve_left = 0
            return True
        return False
