"""Serving engine: continuous batching over a paged KV cache.

Layers (bottom-up):
  allocator — host-side free-list :class:`PageAllocator` (trash page 0,
              ref-counted sharing)
  runner    — paged model execution: prefill-into-pages (reusing the oracle
              ``transformer.prefill``), paged decode step (Pallas kernel in
              ``repro.kernels.paged_attention`` or dense gather reference)
  sampling  — per-request RNG streams (batch-composition independent)
  engine    — :class:`ServeEngine`: admission / batched decode / eviction /
              compaction scheduler

Proven bit-equal to the static-batch oracle (``repro.launch.serve.generate``)
by ``tests/test_serve.py``.
"""
from repro.serve.allocator import OutOfPages, PageAllocator, TRASH_PAGE
from repro.serve.engine import Request, RequestResult, ServeEngine
from repro.serve.runner import check_servable, init_pages
from repro.serve.sampling import request_key, sample_tokens

__all__ = ["OutOfPages", "PageAllocator", "TRASH_PAGE", "Request",
           "RequestResult", "ServeEngine", "check_servable", "init_pages",
           "request_key", "sample_tokens"]
