"""Serving engine: continuous batching over a paged KV cache.

Layers (bottom-up):
  allocator — host-side free-list :class:`PageAllocator` (trash page 0,
              ref-counted sharing)
  runner    — paged model execution: prefill-into-pages (reusing the oracle
              ``transformer.prefill``), paged decode step (Pallas kernel in
              ``repro.kernels.paged_attention`` or dense gather reference)
  sampling  — per-request RNG streams (batch-composition independent)
  faults    — seeded decode-step fault injection (hang/crash) + recovery
              reporting for the supervised serving path
  engine    — :class:`ServeEngine`: admission / overload control (SLO
              deadlines, shedding, priority preemption) / batched decode /
              KV preemption+restore / fault supervision / eviction /
              compaction scheduler

Proven bit-equal to the static-batch oracle (``repro.launch.serve.generate``)
by ``tests/test_serve.py`` — including under preemption/restore, deadline
shedding, and injected decode hangs/crashes.
"""
from repro.serve.allocator import OutOfPages, PageAllocator, TRASH_PAGE
from repro.serve.engine import Request, RequestResult, ServeEngine
from repro.serve.faults import (CRASH, HANG, ServeDrill, ServeFault,
                                ServeFaultInjector, ServeFaultSpec,
                                ServeRecoveryReport, parse_chaos)
from repro.serve.runner import check_servable, init_pages
from repro.serve.sampling import request_key, sample_tokens

__all__ = ["OutOfPages", "PageAllocator", "TRASH_PAGE", "Request",
           "RequestResult", "ServeEngine", "check_servable", "init_pages",
           "request_key", "sample_tokens", "CRASH", "HANG", "ServeDrill",
           "ServeFault", "ServeFaultInjector", "ServeFaultSpec",
           "ServeRecoveryReport", "parse_chaos"]
