"""Paged-KV model runner: prefill into pages, decode against block tables.

Execution contract (what makes the engine oracle-equivalent, pinned by
``tests/test_serve.py``):

* **Prefill** literally runs ``transformer.prefill`` on a contiguous
  single-sequence cache sized exactly to the prompt, then scatters the
  resulting K/V rows into the sequence's pages via its block table — so the
  engine's prefill logits are the *same floats* as the static-batch oracle's.
* **Decode** projects q/k/v through the same ``gqa_project`` /
  ``mla_project`` helpers the oracle uses, writes the new token's K/V into
  the page at ``lengths[b]``, and attends over ``lengths+1`` positions with
  either the Pallas paged kernel (``attention_impl="paged"``) or the dense
  gather reference (``"dense"``) — both masked with the oracle's
  ``NEG_INF`` bias, so padded page tails are exact no-ops.
* Everything is **row-independent** (attention per sequence, MoE routing
  groups = batch rows), so co-batched sequences can never perturb each
  other's tokens — the property continuous batching needs.

Page pools mirror the oracle cache pytree ({prefix, cycles, suffix}); MLA
stores one fused ``c_kv ‖ k_rope`` pool per layer (values are the latent
prefix, ``v_width`` in the kernel), keeping the MLA cache-memory saving.

Compiled callables are cached per ``(cfg.name, …)`` at module level —
jax's own shape cache handles varying batch buckets and prompt lengths.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import resolve_interpret
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_ref)
from repro.models import attention, blocks, moe, transformer
from repro.models.layers import rmsnorm, swiglu


def check_servable(cfg: ModelConfig) -> None:
    """The paged engine serves decoder-only, all-attention, rope/none-pos
    stacks with full (non-windowed) attention or MLA.  Everything else
    (ssm/rglru mixers, sliding-window ring caches, mrope frontends,
    enc-dec) stays on the static-batch oracle path."""
    reasons = []
    if cfg.is_encdec:
        reasons.append("encoder-decoder")
    if cfg.frontend:
        reasons.append(f"frontend={cfg.frontend}")
    if any(k != "attn" for k in cfg.pattern):
        reasons.append("non-attention mixers in block pattern")
    if cfg.attention not in ("full", "mla"):
        reasons.append(f"attention={cfg.attention!r} (need full or mla)")
    if cfg.rope == "mrope":
        reasons.append("mrope positions")
    if reasons:
        raise ValueError(
            f"{cfg.name} is not servable by the paged engine: "
            + "; ".join(reasons))


# ------------------------------------------------------------------ page pools

def _layer_pool(cfg: ModelConfig, num_pages: int, page_size: int, dtype):
    if cfg.attention == "mla":
        m = cfg.mla
        width = m.kv_lora_rank + m.qk_rope_head_dim
        return {"kv": jnp.zeros((num_pages, page_size, 1, width), dtype)}
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((num_pages, page_size, KV, hd), dtype),
            "v": jnp.zeros((num_pages, page_size, KV, hd), dtype)}


def init_pages(cfg: ModelConfig, num_pages: int, page_size: int,
               dtype=jnp.float32):
    """Physical page pools, one per layer, mirroring the oracle cache pytree
    ({"prefix": tuple, "cycles": stacked tuple, "suffix": tuple})."""
    plan = transformer.stack_plan(cfg)
    one = lambda: _layer_pool(cfg, num_pages, page_size, dtype)
    pref = tuple(one() for _ in plan.prefix)
    suff = tuple(one() for _ in plan.suffix)
    if plan.n_cycles:
        cyc = tuple(
            jax.tree.map(
                lambda x: jnp.zeros((plan.n_cycles,) + x.shape, x.dtype),
                one())
            for _ in plan.pattern)
    else:
        cyc = None
    return {"prefix": pref, "cycles": cyc, "suffix": suff}


# ---------------------------------------------------------------- decode step

def _attn_decode(mp, cfg, page_size, xn, pool, tables, lengths, attn_fn,
                 interpret):
    """One layer's paged decode.  xn: (B,1,d) normed hidden; lengths: tokens
    already cached per row (the new token lands at position ``lengths[b]``)."""
    B = xn.shape[0]
    q_pos = lengths[:, None].astype(jnp.int32)
    pidx = jnp.take_along_axis(tables, (lengths // page_size)[:, None],
                               axis=1)[:, 0]
    off = lengths % page_size
    n_valid = lengths + 1

    if cfg.attention == "mla":
        m = cfg.mla
        q_full, c_kv, k_rope = attention.mla_project(mp, cfg, xn, q_pos)
        val = jnp.concatenate([c_kv, k_rope], axis=-1)[:, 0]       # (B, width)
        kv = pool["kv"].at[pidx, off].set(val[:, None, :].astype(
            pool["kv"].dtype))
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        out_lat = attn_fn(q_full[:, 0], kv, None, tables, n_valid,
                          scale=scale, v_width=m.kv_lora_rank,
                          interpret=interpret)
        out = attention.mla_output(mp, cfg, out_lat[:, None])
        return out, {"kv": kv}

    H, hd = cfg.n_heads, cfg.resolved_head_dim
    q, k, v = attention.gqa_project(mp, cfg, xn, q_pos)
    kp = pool["k"].at[pidx, off].set(k[:, 0].astype(pool["k"].dtype))
    vp = pool["v"].at[pidx, off].set(v[:, 0].astype(pool["v"].dtype))
    out = attn_fn(q[:, 0], kp, vp, tables, n_valid,
                  scale=1.0 / math.sqrt(hd), interpret=interpret)
    out = jnp.einsum("bse,ed->bsd", out.reshape(B, 1, H * hd), mp["w_o"])
    return out, {"k": kp, "v": vp}


def _serve_block(bp, cfg, page_size, ffn, h, pool, tables, lengths, attn_fn,
                 interpret):
    """Residual block on the paged path — same math as ``blocks.block_apply``
    (attn mixer only; MoE aux loss dropped, decode never uses it)."""
    mixed, pool = _attn_decode(bp["mixer"], cfg, page_size,
                               rmsnorm(bp["norm1"], h, cfg.norm_eps),
                               pool, tables, lengths, attn_fn, interpret)
    h = h + mixed
    if ffn == "dense":
        h = h + swiglu(bp["ffn"], rmsnorm(bp["norm2"], h, cfg.norm_eps))
    elif ffn == "moe":
        out, _ = moe.moe_apply(bp["ffn"], cfg,
                               rmsnorm(bp["norm2"], h, cfg.norm_eps))
        h = h + out
    return h, pool


def make_decode_fn(cfg: ModelConfig, *, page_size: int,
                   attention_impl: str = "paged", interpret=None):
    """Jitted ``step(params, pages, tokens, lengths, tables) ->
    (logits (B,V), new_pages)``.

    tokens (B,) this step's input tokens · lengths (B,) tokens already in
    cache · tables (B, max_pages) block tables (trash page 0 beyond each
    row's pages; padded rows all-trash with length 0 — row independence
    makes their garbage logits harmless).
    """
    check_servable(cfg)
    if attention_impl not in ("paged", "dense"):
        raise ValueError(f"attention_impl={attention_impl!r}")
    plan = transformer.stack_plan(cfg)
    interp = resolve_interpret(interpret)
    attn_fn = (paged_decode_attention if attention_impl == "paged"
               else paged_decode_attention_ref)
    ffn_prefix = [blocks.ffn_kind(cfg, i) for i in plan.prefix]
    ffn_cycle = [blocks.ffn_kind(cfg, plan.cycle_start + j)
                 for j in range(len(plan.pattern))]
    ffn_suffix = [blocks.ffn_kind(cfg, i) for i in plan.suffix]

    def step(params, pages, tokens, lengths, tables):
        lengths = lengths.astype(jnp.int32)
        tables = tables.astype(jnp.int32)
        h = transformer.embed_tokens(params, cfg, tokens[:, None])
        new_prefix = []
        for i, bp in enumerate(params["prefix"]):
            h, pool = _serve_block(bp, cfg, page_size, ffn_prefix[i], h,
                                   pages["prefix"][i], tables, lengths,
                                   attn_fn, interp)
            new_prefix.append(pool)
        new_cycles = pages["cycles"]
        if plan.n_cycles:
            def body(hh, xs):
                cp, cpools = xs
                outs = []
                for j in range(len(plan.pattern)):
                    hh, pj = _serve_block(cp[j], cfg, page_size, ffn_cycle[j],
                                          hh, cpools[j], tables, lengths,
                                          attn_fn, interp)
                    outs.append(pj)
                return hh, tuple(outs)
            h, new_cycles = jax.lax.scan(
                body, h, (params["cycles"], pages["cycles"]))
        new_suffix = []
        for i, bp in enumerate(params["suffix"]):
            h, pool = _serve_block(bp, cfg, page_size, ffn_suffix[i], h,
                                   pages["suffix"][i], tables, lengths,
                                   attn_fn, interp)
            new_suffix.append(pool)
        logits = transformer._logits(params, cfg, h)[:, 0]
        return logits, {"prefix": tuple(new_prefix), "cycles": new_cycles,
                        "suffix": tuple(new_suffix)}

    return jax.jit(step)


# -------------------------------------------------------------------- prefill

def make_prefill_fn(cfg: ModelConfig, *, page_size: int):
    """Jitted ``prefill(params, pages, prompt (1,P), table (max_pages,)) ->
    (logits (1,V), new_pages)``.

    Runs the *oracle's* ``transformer.prefill`` on a contiguous cache sized
    exactly (1, P), then scatters the cache rows into the sequence's pages —
    identical prefill floats to the static-batch path by construction.
    Compiles once per distinct prompt length (the engine buckets arrivals).
    """
    check_servable(cfg)
    plan = transformer.stack_plan(cfg)

    def prefill(params, pages, prompt, table):
        P = prompt.shape[1]
        table = table.astype(jnp.int32)
        cache = transformer.init_cache(cfg, 1, P)
        logits, cache = transformer.prefill(params, cfg, cache, prompt)
        pos = jnp.arange(P, dtype=jnp.int32)
        pidx = table[pos // page_size]
        off = pos % page_size

        def copy(pool, cl, stacked):
            if cfg.attention == "mla":
                val = jnp.concatenate([cl["c_kv"], cl["k_rope"]], axis=-1)
                if stacked:                       # (n_cycles, 1, P, width)
                    return {"kv": pool["kv"].at[:, pidx, off].set(
                        val[:, 0][:, :, None, :].astype(pool["kv"].dtype))}
                return {"kv": pool["kv"].at[pidx, off].set(
                    val[0][:, None, :].astype(pool["kv"].dtype))}
            if stacked:                           # (n_cycles, 1, P, KV, hd)
                return {"k": pool["k"].at[:, pidx, off].set(
                            cl["k"][:, 0].astype(pool["k"].dtype)),
                        "v": pool["v"].at[:, pidx, off].set(
                            cl["v"][:, 0].astype(pool["v"].dtype))}
            return {"k": pool["k"].at[pidx, off].set(
                        cl["k"][0].astype(pool["k"].dtype)),
                    "v": pool["v"].at[pidx, off].set(
                        cl["v"][0].astype(pool["v"].dtype))}

        new_prefix = tuple(copy(pages["prefix"][i], cache["prefix"][i], False)
                           for i in range(len(plan.prefix)))
        new_suffix = tuple(copy(pages["suffix"][i], cache["suffix"][i], False)
                           for i in range(len(plan.suffix)))
        new_cycles = pages["cycles"]
        if plan.n_cycles:
            new_cycles = tuple(
                copy(pages["cycles"][j], cache["cycles"][j], True)
                for j in range(len(plan.pattern)))
        return logits, {"prefix": new_prefix, "cycles": new_cycles,
                        "suffix": new_suffix}

    return jax.jit(prefill)


# ------------------------------------------------- per-config compile caches

_PREFILL_CACHE: dict = {}
_DECODE_CACHE: dict = {}


def get_prefill_fn(cfg: ModelConfig, *, page_size: int):
    key = (cfg.name, page_size)
    if key not in _PREFILL_CACHE:
        _PREFILL_CACHE[key] = make_prefill_fn(cfg, page_size=page_size)
    return _PREFILL_CACHE[key]


def get_decode_fn(cfg: ModelConfig, *, page_size: int,
                  attention_impl: str = "paged", interpret=None):
    key = (cfg.name, page_size, attention_impl, resolve_interpret(interpret))
    if key not in _DECODE_CACHE:
        _DECODE_CACHE[key] = make_decode_fn(
            cfg, page_size=page_size, attention_impl=attention_impl,
            interpret=interpret)
    return _DECODE_CACHE[key]
