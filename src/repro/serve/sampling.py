"""Per-sequence RNG streams for serving.

The RNG stream of a request must depend only on (base key, request seed,
tokens generated so far) — never on which other sequences share the decode
batch or which slot the request occupies.  That makes seeded sampling
deterministic under continuous batching: the scheduler can admit/evict/
compact freely and every request still sees the exact token stream it would
see alone (``tests/test_serve.py`` pins this).

``sample_tokens`` is shared by the static-batch oracle
(``repro.launch.serve.generate``) and the continuous engine, so the two are
stream-identical by construction for equal (seed, step) pairs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def request_key(base_key, seed):
    """The root RNG key of one request: fold its seed into the base key."""
    return jax.random.fold_in(base_key, seed)


def _sample_one(key, step, logits, temp):
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    k = jax.random.fold_in(key, step)            # stream position = step
    sampled = jax.random.categorical(
        k, logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
    ).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)


@jax.jit
def sample_tokens(logits, keys, steps, temps):
    """Row-wise next-token choice.

    logits (B, V) · keys (B, 2) uint32 request keys · steps (B,) int32
    tokens-generated-so-far · temps (B,) f32.  temp == 0 rows take argmax;
    temp > 0 rows sample ``categorical(fold_in(key, step), logits/temp)``.
    """
    return jax.vmap(_sample_one)(keys, steps, logits, temps)
