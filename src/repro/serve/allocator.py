"""Free-list page allocator for the paged KV cache.

Pure host-side bookkeeping: physical pages live in device pools
(``repro.serve.runner.init_pages``); this class decides who owns which page
index.  Page **0 is reserved as the trash page** — it is never handed out,
so block-table slots of inactive/padded decode rows can all point at it:
their (masked, never-read) writes land somewhere harmless and can never
clobber a live sequence's KV.

Pages are ref-counted so prefix pages can be shared between sequences
(``share`` bumps, ``free`` decrements and only returns a page to the free
list at refcount 0).  The hypothesis property tests in
``tests/test_serve.py`` pin conservation: every page is allocated at most
once at a time, block tables stay disjoint (modulo sharing), and
``free + live == capacity`` after any alloc/free interleaving.
"""
from __future__ import annotations

TRASH_PAGE = 0


class OutOfPages(RuntimeError):
    """Raised when an allocation cannot be satisfied."""


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages of
    ``page_size`` tokens each (page 0 reserved)."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: recently freed pages are re-used first (warm)
        self._free = list(range(num_pages - 1, 0, -1))
        self._refs = {}                       # page -> refcount (allocated)

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return len(self._refs)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (at least one)."""
        return max(1, -(-n_tokens // self.page_size))

    # ----------------------------------------------------------- mutation
    def alloc(self, n: int) -> list[int]:
        """Allocate ``n`` pages (refcount 1 each) or raise :class:`OutOfPages`."""
        if n > len(self._free):
            raise OutOfPages(
                f"need {n} pages, {len(self._free)} free "
                f"of {self.num_pages - 1} allocatable")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, pages: list[int]) -> list[int]:
        """Bump refcounts on already-allocated pages (shared prefix).

        Atomic: an unknown page raises :class:`KeyError` **before** any
        refcount moves, so a bad call can never half-apply."""
        missing = sorted({p for p in pages if p not in self._refs})
        if missing:
            raise KeyError(
                f"cannot share unallocated page(s) {missing}: sharing a "
                "page nobody owns would hand out dangling KV")
        for p in pages:
            self._refs[p] += 1
        return list(pages)

    def free(self, pages: list[int]) -> None:
        """Drop one reference per page; return refcount-0 pages to the pool.

        Atomic: a double free or unknown page raises :class:`KeyError`
        **before** the ledger is touched — duplicates inside one call are
        counted against the refcount too, so ``free([p, p])`` of a
        singly-referenced page cannot corrupt the free list."""
        drops: dict[int, int] = {}
        for p in pages:
            drops[p] = drops.get(p, 0) + 1
        bad = sorted(p for p, n in drops.items()
                     if self._refs.get(p, 0) < n)
        if bad:
            raise KeyError(
                f"double free / unknown page(s) {bad}: freeing more "
                "references than exist would corrupt the refcount ledger")
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
