"""Static analyzer for post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop (scan) body ONCE, which
undercounts FLOPs/bytes/collectives for scan-over-layers models by ~L×.
This module parses the HLO module text, resolves computation call graphs
(while / fusion / call / conditional), multiplies while bodies by their trip
counts (extracted from the loop-condition constants), and accumulates:

  * flops       — dot (2·M·N·K) and convolution ops,
  * hbm_bytes   — operand+result bytes at fusion boundaries (the XLA
                  bytes-accessed convention),
  * coll        — per-collective-type bytes, result-shape sized
                  (all-reduce ×2 for the reduce+broadcast halves),
  * scatter     — result bytes materialized through generic scatter ops
                  (op, fusion root, or a backend scatter-expander while
                  loop identified by op_name metadata; the TL reassembly
                  assertion).

All values describe the per-device SPMD program.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2|token)\[([0-9,]*)\]")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)"
    r"\s+([a-z][\w\-]*)\((.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_ATTR_COMP_RE = re.compile(r"(condition|body|to_apply|calls)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        # computation header: "%name (params) -> shape {" or "ENTRY %name ... {"
        if stripped.endswith("{") and "=" not in stripped.split("->")[0] \
                and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_HDR_RE.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op, rest = m.groups()
            ins = Instr(name, shape, op, rest)
            cur.instrs.append(ins)
            cur.shapes[name] = shape
        else:
            # parameters: "%p = f32[..] parameter(0)" matches; constants with
            # array payloads may not — record shapes anyway
            m2 = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
                          r"(\([^)]*\)|\S+)\s+(\S+)", line)
            if m2:
                cur.shapes[m2.group(1)] = m2.group(2)
    return comps


def _parse_operands(rest: str) -> List[str]:
    depth = 1
    arg = ""
    args: List[str] = []
    for ch in rest:
        if ch == "(":
            depth += 1
            arg += ch
        elif ch == ")":
            depth -= 1
            if depth == 0:
                if arg.strip():
                    args.append(arg)
                break
            arg += ch
        elif ch == "," and depth == 1:
            args.append(arg)
            arg = ""
        else:
            arg += ch
    names = []
    for a in args:
        # operands may be bare ("%copy.10") or typed
        # ("f32[32,64]{1,0} %copy.10") depending on the XLA version
        m = re.search(r"%([\w.\-]+)", a)
        if m:
            names.append(m.group(1))
    return names


def _dot_flops(instr: Instr, comp: Computation) -> float:
    res_elems, _ = _shape_elems_bytes(instr.shape)
    ops = _parse_operands(instr.rest)
    if not ops:
        return 0.0
    lhs_shape = comp.shapes.get(ops[0], "")
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if not m or not lhs_shape:
        return 2.0 * res_elems
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 2.0 * res_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",")] if dims_m.group(2) else []
    k = 1
    for ci in m.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * res_elems * k


def _conv_flops(instr: Instr, comp: Computation) -> float:
    res_elems, _ = _shape_elems_bytes(instr.shape)
    ops = _parse_operands(instr.rest)
    if len(ops) < 2:
        return 2.0 * res_elems
    k_elems, _ = _shape_elems_bytes(comp.shapes.get(ops[1], ""))
    res_dims_m = _SHAPE_RE.search(instr.shape)
    out_feat = 1
    if res_dims_m and res_dims_m.group(2):
        out_feat = int(res_dims_m.group(2).split(",")[-1])
    return 2.0 * res_elems * max(k_elems // max(out_feat, 1), 1)


def _comp_constants_s32(comp: Computation, comps, depth=0) -> List[int]:
    vals: List[int] = []
    if depth > 3 or comp is None:
        return vals
    for ins in comp.instrs:
        if ins.op == "constant" and ins.shape.startswith("s32"):
            m = re.search(r"^(-?\d+)", ins.rest)
            if m:
                vals.append(int(m.group(1)))
        for key, name in _ATTR_COMP_RE.findall(ins.rest):
            vals.extend(_comp_constants_s32(comps.get(name), comps, depth + 1))
    return vals


def _trip_count(cond: Computation, comps) -> int:
    vals = [v for v in _comp_constants_s32(cond, comps) if v > 0]
    return max(vals) if vals else 1


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# a generic scatter that the backend expanded into a loop (XLA:CPU's
# scatter expander) keeps the originating jaxpr primitive in its op_name
# metadata: ".../scatter" (also scatter-add etc.); the leading boundary
# keeps "reduce_scatter" collectives out
_SCATTER_META_RE = re.compile(
    r'op_name="(?:[^"]*/)?scatter(?:[-_][a-z]+)?(?:\[|")')


def _max_tensor_bytes(shape_str: str) -> int:
    """Largest single tensor in an HLO shape string — for a scatter-expander
    while loop this is the scattered result buffer, not the loop carries."""
    best = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dt])
    return best

_HBM_OPS = {"fusion", "dot", "convolution", "custom-call", "scatter",
            "gather", "sort", "reduce", "dynamic-slice",
            "dynamic-update-slice", "copy", "transpose", "broadcast",
            "concatenate", "reshape", "slice", "pad", "iota", "select",
            "add", "multiply", "tanh", "exponential", "rsqrt", "compare"}


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    # generic-scatter accounting: how much result data the module
    # materializes through XLA scatter ops (op or fusion root).  The TL
    # reassembly optimization is asserted on exactly this: the Pallas
    # vb_scatter path must drive scatter_bytes on the fused step to zero.
    scatter_bytes: float = 0.0
    n_scatter: float = 0.0

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.hbm_bytes * k,
                     {t: v * k for t, v in self.coll.items()},
                     self.scatter_bytes * k, self.n_scatter * k)

    def add(self, other: "Costs"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        for t, v in other.coll.items():
            self.coll[t] = self.coll.get(t, 0.0) + v
        self.scatter_bytes += other.scatter_bytes
        self.n_scatter += other.n_scatter

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _fusion_root_op(comp_name: str, comps) -> str:
    comp = comps.get(comp_name)
    if comp and comp.instrs:
        return comp.instrs[-1].op          # ROOT is last
    return ""


def _instr_hbm_bytes(ins: Instr, comp: Computation, comps) -> float:
    """HBM traffic estimate for one instruction.

    Convention: result + operand bytes at fusion boundaries, EXCEPT
    slice-like ops — a dynamic-slice reads only the slice (2× slice bytes),
    a dynamic-update-slice writes only the update region in place (2× update
    bytes).  Without this, scan-carried buffers (KV caches, stacked layer
    params) get charged their full size once per layer per step — orders of
    magnitude above real traffic.
    """
    op = ins.op
    root = op
    attrs = dict(_ATTR_COMP_RE.findall(ins.rest))
    if op == "fusion" and "calls" in attrs:
        root = _fusion_root_op(attrs["calls"], comps)

    _, rb = _shape_elems_bytes(ins.shape)
    operands = _parse_operands(ins.rest)
    ob_list = []
    for name in operands:
        _, b = _shape_elems_bytes(comp.shapes.get(name, ""))
        ob_list.append(b)

    if root == "dynamic-update-slice":
        # in-place: traffic = update region both ways; the big buffer operand
        # and the identically-shaped result alias
        upd = sorted(ob_list)[:-1] if len(ob_list) > 1 else ob_list
        return 2.0 * sum(upd)
    if root in ("dynamic-slice", "slice", "gather"):
        return 2.0 * rb + sum(b for b in ob_list if b <= 8 * rb)
    if root == "scatter":
        big = max(ob_list) if ob_list else 0
        return rb + sum(ob_list) - big + 2.0 * (rb if big > 8 * rb else big)
    return rb + sum(ob_list)


def analyze(text: str) -> Costs:
    comps = parse_module(text)
    memo: Dict[str, Costs] = {}
    called = set()
    for c in comps.values():
        for ins in c.instrs:
            for _, name in _ATTR_COMP_RE.findall(ins.rest):
                called.add(name)
            bm = _BRANCHES_RE.search(ins.rest)
            if bm:
                for n in bm.group(1).split(","):
                    called.add(n.strip().lstrip("%"))

    def cost_of(comp_name: str) -> Costs:
        if comp_name in memo:
            return memo[comp_name]
        comp = comps.get(comp_name)
        out = Costs()
        memo[comp_name] = out
        if comp is None:
            return out
        for ins in comp.instrs:
            op = ins.op
            if op.endswith("-done"):
                continue                     # paired with -start; skip
            attrs = dict(_ATTR_COMP_RE.findall(ins.rest))
            if op == "dot":
                out.flops += _dot_flops(ins, comp)
            elif op == "convolution":
                out.flops += _conv_flops(ins, comp)
            elif op == "while":
                trips = 1
                if "condition" in attrs and attrs["condition"] in comps:
                    trips = _trip_count(comps[attrs["condition"]], comps)
                if "body" in attrs:
                    out.add(cost_of(attrs["body"]).scaled(trips))
            elif op == "conditional":
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    branch_costs = [cost_of(n.strip().lstrip("%"))
                                    for n in bm.group(1).split(",")]
                    if branch_costs:
                        big = max(branch_costs, key=lambda c: c.flops)
                        out.add(big)
            else:
                for key in ("calls", "to_apply"):
                    if key in attrs:
                        out.add(cost_of(attrs[key]))

            root = op
            if op == "fusion" and "calls" in attrs:
                root = _fusion_root_op(attrs["calls"], comps)
            if root == "scatter" or (op == "while"
                                     and _SCATTER_META_RE.search(ins.rest)):
                out.n_scatter += 1
                out.scatter_bytes += _max_tensor_bytes(ins.shape)

            is_coll = any(op.startswith(c) for c in _COLLECTIVES) \
                and not op.endswith("-done")
            if is_coll:
                _, nb = _shape_elems_bytes(ins.shape)
                ctype = next(c for c in _COLLECTIVES if op.startswith(c))
                if ctype == "all-reduce":
                    nb *= 2
                out.coll[ctype] = out.coll.get(ctype, 0.0) + nb

            if op in _HBM_OPS or is_coll:
                out.hbm_bytes += _instr_hbm_bytes(ins, comp, comps)
        return out

    entries = [n for n in comps if n not in called]
    total = Costs()
    for e in entries:
        total.add(cost_of(e))
    return total
