"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = collective_bytes_per_chip / ICI_BW

Sources: ``compiled.cost_analysis()`` supplies flops and bytes accessed for
the per-device SPMD program.  Collective bytes are NOT in cost_analysis —
we parse the post-SPMD optimized HLO (``compiled.as_text()``) and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (documented convention: bytes that land on
a chip's links ≈ result bytes; all-reduce counted twice for the
reduce+broadcast halves).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)"
                       r"\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all tensors in an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type result bytes summed over the (per-device) module."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        op = m.group(3)
        nbytes = shape_bytes(shape_str)
        if op == "all-reduce":
            nbytes *= 2          # reduce + broadcast halves
        out[op] = out.get(op, 0) + nbytes
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops_global: float = 0.0
    peak_memory_per_chip: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs — catches remat/dispatch waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else 0.0

    def to_dict(self):
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def predict_train_collective_bytes(cfg, shape, mesh, params,
                                   remat_mode: str = "tl") -> Dict[str, float]:
    """First-order roofline prediction of the TL train step's per-device
    collective traffic on ``mesh``, in the same convention the HLO analyzer
    measures (result-shape bytes per device; all-reduce counted twice for
    its reduce+broadcast halves).

    The prediction is a *no-CSE upper bound* built from the sharding rules
    themselves (``repro.dist.sharding.param_specs``), not from compiled HLO:

    * ``weights``      — FSDP all-gathers: every leaf with a data/pod axis is
      gathered for the forward pass, and gathered again for the remat-mode
      "tl"/"dots" backward recompute of the tail;
    * ``grads``        — data-parallel gradient reduction of every leaf,
      modeled as an all-reduce of the per-device gradient (2x its bytes:
      full leaf size for FSDP/replicated leaves, per-device shard size for
      TP-only leaves).  XLA may legally lower some
      of these as reduce-scatters (~half the bytes) or CSE re-gathers, which
      is why the measured value sits *below* this bound — the contract
      (asserted in ``tests/test_engine.py``) is
      ``prediction/4 <= measured <= 1.5x prediction``;
    * ``activations``  — tensor-parallel activation all-reduces: ~2 per
      layer in the forward, repeated by the remat recompute, plus ~2 in the
      backward, each of the per-device (B/n_dp, S, d_model) activation.

    Every term vanishes on mesh axes of size 1, so a (1,1) debug mesh
    predicts (and must measure) zero collective bytes.
    """
    import jax as _jax
    from jax.sharding import PartitionSpec as _P
    from repro.dist.sharding import param_specs

    sizes = dict(zip(mesh.axis_names,
                     (mesh.shape[a] for a in mesh.axis_names)))
    n_dp = 1
    for a in ("pod", "data"):
        n_dp *= sizes.get(a, 1)
    n_tp = sizes.get("model", 1)

    pspecs = param_specs(params, cfg, mesh)
    fsdp_bytes = repl_bytes = tp_shard_bytes = 0
    for leaf, spec in zip(
            _jax.tree.leaves(params),
            _jax.tree.leaves(pspecs,
                             is_leaf=lambda x: isinstance(x, _P))):
        nbytes = leaf.size * leaf.dtype.itemsize
        axes = set()
        for entry in spec:
            if entry is None:
                continue
            axes.update(entry if isinstance(entry, tuple) else (entry,))
        if axes & {"pod", "data"}:
            fsdp_bytes += nbytes
        elif "model" in axes:
            # TP-only leaves live (and psum their grads over the data axis)
            # at per-device shard size
            tp_shard_bytes += nbytes // n_tp
        else:
            repl_bytes += nbytes

    weights = 0.0
    grads = 0.0
    if n_dp > 1:
        regather = 2.0 if remat_mode in ("tl", "dots") else 1.0
        weights = regather * float(fsdp_bytes)
        grads = 2.0 * float(fsdp_bytes + repl_bytes + tp_shard_bytes)

    activations = 0.0
    if n_tp > 1:
        d_model = getattr(cfg, "d_model", 0)
        n_layers = getattr(cfg, "n_layers", 0)
        act = (shape.global_batch // max(n_dp, 1)) * shape.seq_len \
            * d_model * 4
        per_layer = 4.0 if remat_mode in ("tl", "dots") else 2.0
        per_layer += 2.0                          # backward-pass psums
        activations = 2.0 * per_layer * n_layers * act

    total = weights + grads + activations
    return {"weights": weights, "grads": grads, "activations": activations,
            "total": total, "n_dp": n_dp, "n_tp": n_tp,
            "fsdp_param_bytes": float(fsdp_bytes),
            "tp_shard_param_bytes": float(tp_shard_bytes),
            "replicated_param_bytes": float(repl_bytes)}


def predict_reassembly_hbm_bytes(x1_bytes: float, dl_bytes: float = 0.0,
                                 dx1_bytes: float = 0.0, *,
                                 strategy: str = "xla") -> Dict[str, float]:
    """Roofline prediction of the virtual-batch reassembly's HBM *write*
    traffic per fused step, by strategy.

    Convention (matches ``hlo_flops``'s scatter accounting): each payload
    tensor's reassembled buffer costs

    * ``"xla"``    — 2× its bytes: XLA's generic ``.at[perm].set`` lowering
      first materializes the zero-initialized destination and then updates
      every row, so the reassembled X^(1) is written twice even though the
      permutation covers every destination row;
    * ``"pallas"`` — 1× its bytes: the ``vb_scatter`` kernel streams each
      destination row exactly once (no zeros materialization).

    Reads of the concatenated payloads (1× per tensor) are identical across
    strategies and excluded.  The dropped 1× of X^(1) is the "materialized
    once, not twice" contract asserted on the compiled fused step by the
    scatter accounting in ``tests/test_analysis.py``.
    """
    if strategy not in ("xla", "pallas"):
        raise ValueError(f"unknown reassembly strategy: {strategy!r}")
    mult = 2.0 if strategy == "xla" else 1.0
    tensors = {"x1": float(x1_bytes), "delta_L": float(dl_bytes),
               "dx1": float(dx1_bytes)}
    out = {k: mult * v for k, v in tensors.items()}
    out["write_multiplier"] = mult
    out["total"] = sum(mult * v for v in tensors.values())
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N(_active)·tokens for training; 2·N for one forward
    token-pass (prefill), 2·N per generated token for decode."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def summarize(artifact: dict) -> str:
    r = artifact
    return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:9s} "
            f"C={r['t_compute']:.3e}s M={r['t_memory']:.3e}s "
            f"N={r['t_collective']:.3e}s -> {r['bottleneck']:10s} "
            f"useful={r['useful_flops_ratio']:.2f}")
