"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(artifact_dir="experiments/artifacts", tag="baseline"):
    arts = {}
    for p in glob.glob(os.path.join(artifact_dir, f"*__{tag}.json")):
        with open(p) as f:
            a = json.load(f)
        arts[(a["arch"], a["shape"], a["mesh"])] = a
    return arts


def fmt_bytes(n):
    return f"{n/2**30:.1f}G" if n >= 2**30 else f"{n/2**20:.0f}M"


def roofline_table(arts, mesh="single"):
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| useful | mem/chip | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in arts})
    for arch in archs:
        for shape in SHAPE_ORDER:
            a = arts.get((arch, shape, mesh))
            if a is None:
                continue
            if a["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | "
                             f"*designed skip: full-attention long-context* "
                             f"| — | — | — |")
                continue
            if a["status"] != "ok":
                lines.append(f"| {arch} | {shape} | FAILED ({a['status']}) "
                             f"| | | | | | |")
                continue
            coll = ", ".join(f"{k}:{fmt_bytes(v)}"
                             for k, v in sorted(a["coll_breakdown"].items()))
            lines.append(
                f"| {arch} | {shape} | {a['t_compute']:.2e}s "
                f"| {a['t_memory']:.2e}s | {a['t_collective']:.2e}s "
                f"| **{a['bottleneck']}** | {a['useful_flops_ratio']:.2f} "
                f"| {fmt_bytes(a['peak_memory_per_chip'])} | {coll} |")
    return "\n".join(lines)


def dryrun_summary(arts):
    ok = [a for a in arts.values() if a["status"] == "ok"]
    sk = [a for a in arts.values() if a["status"] == "skipped"]
    bad = [a for a in arts.values() if a["status"] not in ("ok", "skipped")]
    lines = [f"- compiled OK: **{len(ok)}**, designed skips: {len(sk)}, "
             f"failures: {len(bad)}"]
    for mesh in ("single", "multi"):
        sub = [a for a in ok if a["mesh"] == mesh]
        if sub:
            t = sum(a["t_compile_s"] for a in sub)
            lines.append(f"- {mesh}-pod: {len(sub)} programs, total XLA "
                         f"compile {t:.0f}s, largest HLO "
                         f"{max(a['hlo_lines'] for a in sub)} lines")
    return "\n".join(lines)


def bottleneck_ranking(arts, mesh="single"):
    """Rank pairs for hillclimb selection."""
    rows = []
    for (arch, shape, m), a in arts.items():
        if m != mesh or a["status"] != "ok":
            continue
        dom = max(a["t_compute"], a["t_memory"], a["t_collective"])
        frac = a["t_compute"] / dom if dom else 0
        rows.append((arch, shape, a["bottleneck"], dom, frac,
                     a["useful_flops_ratio"]))
    rows.sort(key=lambda r: r[4])      # worst compute-fraction first
    return rows


if __name__ == "__main__":
    arts = load()
    print(dryrun_summary(arts))
    print()
    print(roofline_table(arts, "single"))
