from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     collective_bytes, model_flops,
                                     shape_bytes, summarize)

__all__ = ["Roofline", "collective_bytes", "model_flops", "shape_bytes",
           "summarize", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
