from repro.data.datasets import (Dataset, iid_images, imbalanced_binary,
                                 shard_cluster, shard_iid, shard_noniid,
                                 tabular, text_tokens)
from repro.data.pipeline import (NodeShard, VirtualBatchLoader, shard_corpus,
                                 synthetic_corpus)

__all__ = ["Dataset", "iid_images", "imbalanced_binary", "shard_cluster",
           "shard_iid", "shard_noniid", "tabular", "text_tokens",
           "NodeShard", "VirtualBatchLoader", "shard_corpus",
           "synthetic_corpus"]
