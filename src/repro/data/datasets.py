"""Synthetic dataset generators mirroring the paper's six dataset families.

The offline container has no MNIST/CIFAR/NICO/MIMIC-IV/BANK/IMDB, so each
family is replaced by a generator with the same *statistical shape* — the
property that drives the paper's comparisons (IID vs non-IID vs imbalanced
vs text).  EXPERIMENTS.md validates the paper's relative orderings
(TL == CL > FL/SL/SFL), not absolute dataset numbers.

  iid_images        — balanced K-class Gaussian-blob "images"   (MNIST/CIFAR)
  noniid_contexts   — class distribution shifts per node shard  (NICO)
  imbalanced_binary — rare positive class, cluster-partitioned  (MIMIC/BANK)
  text_tokens       — token sequences with class-dependent n-gram stats (IMDB)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class Dataset:
    x: np.ndarray
    y: np.ndarray
    n_classes: int
    kind: str

    def split(self, frac: float = 0.8, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.x))
        k = int(len(idx) * frac)
        tr, te = idx[:k], idx[k:]
        return (Dataset(self.x[tr], self.y[tr], self.n_classes, self.kind),
                Dataset(self.x[te], self.y[te], self.n_classes, self.kind))


def iid_images(n: int = 2000, side: int = 16, n_classes: int = 10,
               seed: int = 0, noise: float = 0.35) -> Dataset:
    """Gaussian class prototypes + noise, (n, side, side, 1) images."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(n_classes, side, side, 1)).astype(np.float32)
    y = rng.integers(0, n_classes, n)
    x = protos[y] + noise * rng.normal(size=(n, side, side, 1)).astype(np.float32)
    return Dataset(x.astype(np.float32), y.astype(np.int64), n_classes, "iid_images")


def tabular(n: int, d: int, n_classes: int, seed: int, *, margin: float = 1.0,
            noise: float = 0.5) -> Dataset:
    rng = np.random.default_rng(seed)
    protos = margin * rng.normal(size=(n_classes, d))
    y = rng.integers(0, n_classes, n)
    x = protos[y] + noise * rng.normal(size=(n, d))
    return Dataset(x.astype(np.float32), y.astype(np.int64), n_classes, "tabular")


def imbalanced_binary(n: int = 3000, d: int = 32, pos_frac: float = 0.15,
                      seed: int = 0) -> Dataset:
    """Rare-positive tabular data (MIMIC-IV / BANK shape)."""
    rng = np.random.default_rng(seed)
    n_pos = int(n * pos_frac)
    w = rng.normal(size=(d,))
    x = rng.normal(size=(n, d))
    margin = x @ w
    order = np.argsort(-margin)
    y = np.zeros(n, np.int64)
    y[order[:n_pos]] = 1
    x = x + 0.4 * rng.normal(size=(n, d))
    return Dataset(x.astype(np.float32), y, 2, "imbalanced_binary")


def text_tokens(n: int = 2000, seq_len: int = 32, vocab: int = 256,
                n_classes: int = 2, seed: int = 0) -> Dataset:
    """Class-dependent unigram mixtures (IMDB sentiment shape)."""
    rng = np.random.default_rng(seed)
    class_logits = rng.normal(size=(n_classes, vocab)) * 1.2
    y = rng.integers(0, n_classes, n)
    probs = np.exp(class_logits) / np.exp(class_logits).sum(-1, keepdims=True)
    x = np.stack([rng.choice(vocab, seq_len, p=probs[c]) for c in y])
    return Dataset(x.astype(np.int64), y.astype(np.int64), n_classes, "text")


# --------------------------------------------------------------- sharding

def shard_iid(ds: Dataset, n_nodes: int, seed: int = 0) -> List[Dataset]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds.x))
    return [Dataset(ds.x[part], ds.y[part], ds.n_classes, ds.kind)
            for part in np.array_split(idx, n_nodes)]


def shard_noniid(ds: Dataset, n_nodes: int, *, alpha: float = 0.3,
                 seed: int = 0) -> List[Dataset]:
    """Dirichlet label-skew partition — the paper's non-IID node setting
    (NICO contexts / K-Means-cluster partitioning of MIMIC/BANK)."""
    rng = np.random.default_rng(seed)
    by_class = [np.nonzero(ds.y == c)[0] for c in range(ds.n_classes)]
    shards: List[List[int]] = [[] for _ in range(n_nodes)]
    for idx_c in by_class:
        rng.shuffle(idx_c)
        props = rng.dirichlet([alpha] * n_nodes)
        cuts = (np.cumsum(props)[:-1] * len(idx_c)).astype(int)
        for shard, part in zip(shards, np.split(idx_c, cuts)):
            shard.extend(part.tolist())
    out = []
    for shard in shards:
        part = np.asarray(sorted(shard), np.int64)
        if len(part) == 0:                      # ensure non-empty shards
            part = rng.integers(0, len(ds.x), 2)
        out.append(Dataset(ds.x[part], ds.y[part], ds.n_classes, ds.kind))
    return out


def shard_cluster(ds: Dataset, n_nodes: int, seed: int = 0) -> List[Dataset]:
    """K-Means-style feature-cluster partition (paper §4.1.1 for MIMIC/BANK)."""
    rng = np.random.default_rng(seed)
    flat = ds.x.reshape(len(ds.x), -1).astype(np.float64)
    centers = flat[rng.choice(len(flat), n_nodes, replace=False)]
    for _ in range(10):                          # lightweight Lloyd iterations
        d2 = ((flat[:, None] - centers[None]) ** 2).sum(-1)
        assign = d2.argmin(1)
        for k in range(n_nodes):
            sel = flat[assign == k]
            if len(sel):
                centers[k] = sel.mean(0)
    out = []
    for k in range(n_nodes):
        part = np.nonzero(assign == k)[0]
        if len(part) == 0:
            part = rng.integers(0, len(ds.x), 2)
        out.append(Dataset(ds.x[part], ds.y[part], ds.n_classes, ds.kind))
    return out
