"""Token data pipeline for LM training (production path).

Synthetic corpus -> node shards -> virtual batches (Algorithm 1) -> device
batches.  The virtual-batch sampler is the bridge between the paper's
orchestrator plan and the pjit train step: each virtual batch's traversal
plan assigns its rows to logical nodes = (pod, data) mesh coordinates, so
the array handed to ``train_step`` is laid out node-major and the GSPMD
batch sharding puts every node's rows on that node's chips.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.virtual_batch import (IndexRange, VirtualBatchPlan,
                                      create_virtual_batches)


def synthetic_corpus(n_docs: int, seq_len: int, vocab: int, seed: int = 0,
                     n_styles: int = 8) -> np.ndarray:
    """Markov-ish token documents with per-style statistics, (n, seq+1)."""
    rng = np.random.default_rng(seed)
    style_logits = rng.normal(size=(n_styles, vocab)).astype(np.float64) * 1.5
    style_probs = np.exp(style_logits)
    style_probs /= style_probs.sum(-1, keepdims=True)
    styles = rng.integers(0, n_styles, n_docs)
    docs = np.stack([rng.choice(vocab, seq_len + 1, p=style_probs[s])
                     for s in styles])
    return docs.astype(np.int32)


@dataclass
class NodeShard:
    node_id: int
    docs: np.ndarray          # (n_local, seq+1)

    def index_range(self) -> IndexRange:
        return IndexRange(self.node_id, len(self.docs))


def shard_corpus(docs: np.ndarray, n_nodes: int, seed: int = 0) -> List[NodeShard]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(docs))
    return [NodeShard(i, docs[part])
            for i, part in enumerate(np.array_split(idx, n_nodes))]


class VirtualBatchLoader:
    """Iterates (tokens, targets) arrays assembled per the traversal plan.

    Rows inside each emitted batch are ordered *node-major in traversal
    order* so that sharding dim 0 over the (pod, data) axes places each
    node's rows on its own chips — the physical realization of the
    orchestrator's node-visit schedule.
    """

    def __init__(self, shards: List[NodeShard], batch_size: int, *,
                 seed: int = 0, epochs: Optional[int] = None):
        self.shards = {s.node_id: s for s in shards}
        self.batch_size = batch_size
        self.seed = seed
        self.epochs = epochs

    def plan(self, epoch: int) -> VirtualBatchPlan:
        ranges = [s.index_range() for s in self.shards.values()]
        return create_virtual_batches(ranges, self.batch_size,
                                      seed=self.seed + epoch)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        epoch = 0
        while self.epochs is None or epoch < self.epochs:
            plan = self.plan(epoch)
            for vb in plan.batches:
                rows, pos = [], []
                for seg in vb.traversal:
                    rows.append(self.shards[seg.node_id].docs[seg.local_indices])
                    pos.append(seg.batch_positions)
                data = np.concatenate(rows, axis=0)
                # positions: each node-major row's global (shuffled) batch
                # position — consumed by the engine's reassembly path,
                # dropped otherwise (never device-transferred as-is)
                yield {"tokens": data[:, :-1].astype(np.int32),
                       "targets": data[:, 1:].astype(np.int32),
                       "positions": np.concatenate(pos).astype(np.int32)}
            epoch += 1
