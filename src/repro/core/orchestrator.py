"""TL orchestrator — Algorithm 2: traversal scheduling, activation/gradient
retrieval, centralized BP, model redistribution.

Since the planner/executor split, *planning* (Algorithm 1) lives in
``repro.core.plan``: the orchestrator executes whatever
:class:`~repro.core.plan.TraversalPlan` its configured planner produces
(``build_plan`` is a thin shim; ``execute_plan`` runs an epoch of an
already-built plan, which is how the hierarchical orchestrator drives its
subtree executors).  Planning knobs group under ``plan=PlanSpec(...)``;
the old ``seed=``/``replicas=``/``recovery=`` spellings still work with a
``DeprecationWarning``.

Centralized phase (paper §3.3.2): the orchestrator reassembles the virtual
batch's first-layer activations X^(1) in batch order, *recomputes* all
deeper activations with the current parameters (eq. 4–5), backpropagates
from the aggregated last-layer gradients (eq. 6–11), adds the node-supplied
first-layer weight gradients, applies the update (eq. 13–14), and
redistributes the model.

The orchestrator also verifies eq. 12: its own recomputed ∂L/∂X^(1) must
match the aggregate of the node-submitted first-layer gradients — the
paper's "ensuring consistency with the recalculated forward pass".

Two execution paths produce the *same* update:

* fused (default) — one jitted centralized-BP step per virtual batch:
  the per-node payloads are concatenated and reassembled over the
  concatenated ``batch_positions``, the tail vjp + eq. 12 consistency
  check + optimizer update run as one compiled function (cached across
  virtual batches; ``donate=True`` additionally donates params/opt_state
  buffers), and loss/accuracy stay device-resident so the host syncs once
  per epoch.  ``reassembly`` selects how the batch is put back together:
  ``"xla"`` keeps one generic ``.at[perm].set`` scatter per payload tensor
  (zeros-init + row updates — two HBM writes of each reassembled tensor);
  ``"pallas"`` routes all three payloads through the fused
  ``repro.kernels.vb_scatter`` row-gather kernel — one launch, one HBM
  pass, no zeros materialization (same values bit-for-bit);
* eager (``fused=False``) — the op-by-op reference path with per-node
  scatters and an un-jitted vjp, kept as the lossless oracle and the
  benchmark baseline.

Both paths accumulate first-layer weight gradients only over the leaves
``first_layer`` actually reads (the rest are structural zeros), instead of
allocating and tree-adding a full zeros param-pytree per node visit.

Each TL step is split into a *producer* half (``_collect_visits`` — model
redistribution + node visits) and a *consumer* half (``apply_update`` —
centralized BP + optimizer).  ``pipelined=True`` routes ``train_epoch``
through the double-buffered epoch engine (``repro.core.pipeline``), which
overlaps batch k+1's production with batch k's consumption — a pure
reordering of the same arithmetic, proven by the cross-path equivalence
test grid.
"""
from __future__ import annotations

import functools
import operator
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import (FaultEvent, NodeHealth, RecoveryPolicy,
                               UnrecoverableFault, VisitDropped)
from repro.core.node import (TLNode, add_first_layer_grads,
                             first_layer_grad_leaves)
from repro.core.plan import Planner, PlanSpec, TraversalPlan
from repro.core.transport import Transport
from repro.core.virtual_batch import assert_covers_traversal


@dataclass
class StepStats:
    loss: float
    acc: float
    grad_consistency: float     # max |orchestrator dX1 - aggregated node dX1|


# sentinel distinguishing "legacy planning kwarg not passed" from any value
_LEGACY_UNSET = object()


def _resolve_plan_spec(plan, *, seed, replicas, recovery) -> PlanSpec:
    """Fold the constructor's planning arguments into one PlanSpec.

    ``plan`` may be a :class:`PlanSpec`, a bare :class:`Planner`, or None.
    The pre-split spellings (``seed=``/``replicas=``/``recovery=`` as
    separate keywords) still work but are deprecated in favor of
    ``plan=PlanSpec(...)``; combining them with an explicit PlanSpec is an
    error rather than a silent precedence rule.
    """
    legacy = {"seed": seed, "replicas": replicas, "recovery": recovery}
    given = {k: v for k, v in legacy.items() if v is not _LEGACY_UNSET}
    if isinstance(plan, PlanSpec):
        if given:
            raise ValueError(
                f"planning knobs passed twice: move {'/'.join(given)} "
                "inside plan=PlanSpec(...)")
        return plan
    for k in given:
        warnings.warn(
            f"TLOrchestrator({k}=...) is deprecated; pass "
            f"plan=PlanSpec({k}=...) instead",
            DeprecationWarning, stacklevel=3)
    if plan is not None and not isinstance(plan, Planner):
        raise TypeError(
            f"plan= must be a PlanSpec or a Planner, got {type(plan)!r}")
    return PlanSpec(
        planner=plan,
        seed=(0 if seed is _LEGACY_UNSET else seed),
        replicas=(None if replicas is _LEGACY_UNSET else replicas),
        recovery=(None if recovery is _LEGACY_UNSET else recovery))


class TLOrchestrator:
    def __init__(self, model, nodes: Sequence[TLNode], optimizer,
                 transport: Optional[Transport] = None, *,
                 plan: Optional[object] = None,
                 batch_size: int = 64, seed=_LEGACY_UNSET,
                 compute_time_fn: Callable[[int], float] = lambda n: 0.0,
                 bp_time_fn: Callable[[int], float] = lambda n: 0.0,
                 check_consistency: bool = True,
                 cache_model_per_epoch: bool = False,
                 fused: bool = True, donate: bool = False,
                 pipelined: bool = False, reassembly: str = "xla",
                 replicas: Optional[Dict[int, TLNode]] = _LEGACY_UNSET,
                 recovery: Optional[RecoveryPolicy] = _LEGACY_UNSET):
        self.model = model
        self.nodes = list(nodes)
        self.opt = optimizer
        self.transport = transport or Transport()
        # planning knobs live in a PlanSpec (repro.core.plan); the flat
        # attributes stay as the public read surface
        spec = _resolve_plan_spec(plan, seed=seed, replicas=replicas,
                                  recovery=recovery)
        self.plan_spec = spec
        self.planner: Planner = spec.resolve_planner()
        self.batch_size = (batch_size if spec.batch_size is None
                           else spec.batch_size)
        self.seed = spec.seed
        self.compute_time_fn = compute_time_fn
        # simulated centralized-BP time per virtual batch (size N); the
        # serial path ticks it on the clock, the pipelined engine overlaps
        # it with batch k+1's visits (default 0: clock unchanged)
        self.bp_time_fn = bp_time_fn
        self.check_consistency = check_consistency
        # §5.2 caching: redistribute the model once per epoch instead of once
        # per virtual batch (bandwidth optimization; changes staleness!)
        self.cache_model_per_epoch = cache_model_per_epoch
        # fused: run the centralized-BP phase as one jitted step (see module
        # docstring).  donate=True additionally donates the params/opt_state
        # buffers to the step — callers must not hold references to them.
        if donate and cache_model_per_epoch:
            # nodes keep aliases of self.params for the whole epoch under
            # model caching; donating those buffers after the first virtual
            # batch would hand deleted arrays to every later visit
            raise ValueError("donate=True is incompatible with "
                             "cache_model_per_epoch=True: nodes alias the "
                             "donated parameter buffers across batches")
        self.fused = fused
        self.donate = donate
        # reassembly: how the fused/contrib steps put the virtual batch back
        # in global order — "xla" (generic scatter) or "pallas" (the fused
        # vb_scatter kernel); numerically identical, see module docstring
        if reassembly not in ("xla", "pallas"):
            raise ValueError(f"unknown reassembly strategy: {reassembly!r}")
        self.reassembly = reassembly
        # pipelined: route train_epoch through the double-buffered epoch
        # engine (repro.core.pipeline) — batch k+1's visits are produced
        # while batch k's centralized BP consumes; a pure reordering of the
        # same math (see the cross-path equivalence test grid)
        self.pipelined = pipelined
        # fault recovery (repro.core.faults): replicas hold bit-identical
        # copies of a primary node's shard; the recovery policy governs
        # retries/backoff/failover/eviction when the transport's fault lanes
        # drop visit payloads.  Recovery is lossless: a retried or
        # failed-over visit produces the same wire payload, so losses and
        # params stay bit-equal to the fault-free run (tests/test_faults.py).
        self.replicas: Dict[int, TLNode] = dict(spec.replicas or {})
        self.recovery = spec.recovery or RecoveryPolicy()
        self.fault_log: List[FaultEvent] = []
        self._health: Dict[int, NodeHealth] = {}
        self.params = None
        self.opt_state = None
        self._epoch = 0
        self._step = 0              # global virtual-batch counter (ckpt id)
        self._active_plan: Optional[TraversalPlan] = None
        self._fused_step = None
        self._contrib_step = None
        self._gw1_leaves = None

    # ------------------------------------------------------------- lifecycle
    def initialize(self, key):
        self.params = self.model.init(key)
        self.opt_state = self.opt.init(self.params)

    def build_plan(self, epoch: int) -> TraversalPlan:
        """Thin shim over the configured :class:`~repro.core.plan.Planner`.

        Index-range retrieval stays here because it is a transport
        interaction (step 1 of Algorithm 1): ranges are queried — and
        charged — exactly once per epoch at the planning orchestrator,
        never re-queried per subtree in a hierarchical run."""
        ranges = [self.transport.send("index_range", n.index_range())
                  for n in self.nodes]
        return self.planner.plan(ranges, batch_size=self.batch_size,
                                 seed=self.seed, epoch=epoch)

    # ---------------------------------------------------------- one TL step
    def train_batch(self, vb, node_by_id) -> StepStats:
        results, order = self._collect_visits(vb, node_by_id)
        return self.apply_update(vb, results, order)

    def apply_update(self, vb, results, order) -> StepStats:
        """Consumer half of one TL step: centralized BP + optimizer update
        from already-collected visit payloads.  Advances the simulated clock
        by ``bp_time_fn(N)`` — the quantity the pipelined engine overlaps
        with the next batch's visits."""
        self.transport.tick(self.bp_time_fn(vb.size))
        self._step += 1
        if self.fused:
            return self._train_batch_fused(vb, results, order)
        return self._train_batch_eager(vb, results, order)

    def _executor(self, node_id: int, node_by_id) -> TLNode:
        """The node that should execute ``node_id``'s segments right now:
        the primary, or — once the health tracker evicted it mid-epoch —
        its replica (traversal re-planning without touching the plan: the
        segment's local indices and batch positions are identical on the
        replica's bit-identical shard)."""
        h = self._health.get(node_id)
        if h is not None and h.evicted and node_id in self.replicas:
            return self.replicas[node_id]
        return node_by_id[node_id]

    def _collect_visits(self, vb, node_by_id, *, issue: bool = False):
        """Producer half of one TL step: distributed FP along the traversal
        plan (pipelined: transfers of one node overlap the next node's
        compute — paper §3.2).  ``issue=True`` (the epoch engine's mode)
        uses :meth:`TLNode.issue_visit` so no payload is host-materialized
        while a previous batch's BP is still in flight.  Every visit runs
        under the transport's fault lane and the recovery policy (retry,
        backoff, replica failover); the reassembly invariant — each virtual
        batch row assembled exactly once — is re-verified after recovery."""
        results, order = {}, []

        if not self.cache_model_per_epoch:
            with self.transport.parallel():
                for seg in vb.traversal:
                    node = self._executor(seg.node_id, node_by_id)
                    node.receive_model(
                        self.transport.send("model", self.params))

        with self.transport.parallel():
            for seg in vb.traversal:
                wire = self._visit_with_recovery(vb, seg, node_by_id,
                                                 issue=issue)
                results[seg.node_id] = (seg, wire)
                order.append(seg.node_id)
        # a restricted (subtree) batch covers a subset of the rows, so the
        # invariant is checked against the batch's own traversal — for a
        # full batch that is exactly the 0..N-1 partition check
        assert_covers_traversal(vb, [results[nid][0] for nid in order])
        return results, order

    def _visit_with_recovery(self, vb, seg, node_by_id, *, issue: bool):
        """One traversal segment, retried/re-routed until a payload lands.

        Attempt ``a`` runs inside ``transport.fault_lane((epoch, batch,
        node, a))`` — the seeded verdict is a pure function of that key, so
        serial/pipelined/resumed execution all see the same faults.  On a
        drop: linear backoff on the simulated clock, failover to the
        node's replica after ``retries_before_failover`` failed attempts
        (re-sending the model the primary was visiting with — charged), and
        mid-epoch eviction of the primary after ``evict_after`` cumulative
        failures.  Raises :class:`UnrecoverableFault` once
        ``max_attempts`` is exhausted — never a partial virtual batch."""
        tr, pol = self.transport, self.recovery
        primary = node_by_id[seg.node_id]
        executor = self._executor(seg.node_id, node_by_id)
        failed_over = executor is not primary
        attempt = 0
        # one segment's attempts are sequential on the wire: chain them so
        # a retried upload adds to the parallel window's cost instead of
        # hiding under its max() — the retry cost must be visible on the
        # simulated clock, not just in the byte counters
        with tr.chain():
            while True:
                key = (self._epoch, vb.batch_id, seg.node_id, attempt)
                try:
                    with tr.fault_lane(key):
                        tr.tick(
                            self.compute_time_fn(len(seg.local_indices)))
                        visit = (executor.issue_visit if issue
                                 else executor.forward_visit)
                        fp = visit(seg.local_indices, vb.size)
                        # the wire format is protocol-defined: stats travel
                        # as fixed 4-byte scalars whether the producing path
                        # materialized them on the host (eager serial) or
                        # left them device-resident (jitted / pipelined) —
                        # byte accounting must not depend on *when* the
                        # host syncs
                        return tr.send(
                            "activations_grads",
                            {"x1": fp.x1, "delta_L": fp.delta_L,
                             "dx1": fp.dx1, "gw1": fp.gw1,
                             "loss_sum": jnp.asarray(fp.loss_sum,
                                                     jnp.float32),
                             "n_correct": jnp.asarray(fp.n_correct,
                                                      jnp.int32)},
                            compressible=True, key=seg.node_id)
                except VisitDropped:
                    attempt += 1
                    h = self._health.setdefault(seg.node_id, NodeHealth())
                    h.failures += 1
                    has_replica = seg.node_id in self.replicas
                    if (has_replica and not h.evicted
                            and h.failures >= pol.evict_after):
                        # re-plan: the primary is done for; all later
                        # segments of this node route straight to the
                        # replica
                        h.evicted = True
                        self.fault_log.append(FaultEvent(key, "evict"))
                    # fail over when the retry budget says so — or as the
                    # last act before giving up, so a configured replica is
                    # always tried even under a retries_before_failover >
                    # max_attempts misconfiguration
                    if (not failed_over and has_replica
                            and (h.evicted
                                 or attempt >= pol.retries_before_failover
                                 or attempt >= pol.max_attempts)):
                        executor = self.replicas[seg.node_id]
                        failed_over = True
                        # the replica must visit with exactly the params the
                        # primary held (bit-identical recovery) — re-sent
                        # and charged like any model redistribution
                        executor.receive_model(
                            tr.send("model", primary.params))
                        self.fault_log.append(FaultEvent(key, "failover"))
                    elif attempt >= pol.max_attempts:
                        raise UnrecoverableFault(
                            f"traversal segment for node {seg.node_id} "
                            f"(batch {vb.batch_id}, epoch {self._epoch}) "
                            f"still failing after {attempt} attempts and "
                            f"no {'further ' if has_replica else ''}replica "
                            "to fail over to") from None
                    else:
                        self.fault_log.append(FaultEvent(key, "retry"))
                    if pol.backoff_s:
                        tr.tick(pol.backoff_s * attempt)

    # ---- first-layer gradient support (structural-zero pruning) -----------
    def _gw1_leaf_indices(self):
        if self._gw1_leaves is None:
            # which param leaves first_layer reads: traced once, reused for
            # every batch.  A dummy input built from any node's shard works
            # because the dependency structure is shape-independent.
            node = self.nodes[0]
            self._gw1_leaves = first_layer_grad_leaves(
                self.model, self.params, node.x[:1])
        return self._gw1_leaves

    @staticmethod
    def _as_leaf_dict(gw1, leaf_indices):
        """Normalize a node's gw1 payload to {leaf_index: array}."""
        if isinstance(gw1, dict) and all(isinstance(k, int) for k in gw1):
            return gw1
        flat = jax.tree_util.tree_leaves(gw1)
        return {i: flat[i] for i in leaf_indices}

    # --------------------------------------------------- fused (jitted) path
    def _build_fused_step(self, reassemble: str):
        model, opt = self.model, self.opt
        check = self.check_consistency

        def step(params, opt_state, x1_cat, dL_cat, dx1_cat, perm, gw1s):
            # reassemble the virtual batch in global shuffled order
            # (positions partition 0..N-1): one generic scatter per tensor
            # ("xla") or all three payloads in one fused kernel pass
            # ("pallas" — repro.kernels.vb_scatter)
            if reassemble == "pallas":
                from repro.kernels.vb_scatter import scatter_rows, vb_scatter
                if check:
                    x1, dL, dx1_nodes = vb_scatter(x1_cat, dL_cat, dx1_cat,
                                                   perm)
                else:
                    # dx1 is only consumed by the eq. 12 check; keep the
                    # dead payload out of the fused pass (XLA cannot DCE
                    # one output of the kernel call)
                    x1, dL = scatter_rows(perm, (x1_cat, dL_cat))
                    dx1_nodes = None
            else:
                x1 = jnp.zeros_like(x1_cat).at[perm].set(x1_cat)
                dL = jnp.zeros_like(dL_cat).at[perm].set(dL_cat)
                dx1_nodes = (jnp.zeros_like(dx1_cat).at[perm].set(dx1_cat)
                             if check else None)
            # centralized BP: recompute activations from X^(1) (eq. 4–5),
            # backprop from aggregated δ^(L) (eq. 6–11)
            _, pull = jax.vjp(
                lambda p, h: model.tail_layers(p, h), params, x1)
            g_tail, dx1_orch = pull(dL)
            acc: Dict[int, jax.Array] = {}
            for g in gw1s:
                for i, leaf in g.items():
                    acc[i] = leaf if i not in acc else acc[i] + leaf
            grads = add_first_layer_grads(g_tail, acc)
            if check:                                          # eq. 12
                cons = jnp.max(jnp.abs(dx1_orch - dx1_nodes))
            else:
                cons = jnp.full((), jnp.nan, jnp.float32)
            # parameter update (eq. 13–14)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, cons

        donate = (0, 1) if self.donate else ()
        return jax.jit(step, donate_argnums=donate)

    def _get_fused_step(self, reassemble: Optional[str] = None):
        """Cached jitted centralized-BP step.  ``reassemble`` overrides the
        orchestrator's configured strategy ("xla" | "pallas"); the
        orchestrator's own strategy is compile-once cached, an explicit
        override builds a fresh step (strategy experiments/benchmarks)."""
        strategy = self.reassembly if reassemble is None else reassemble
        if strategy != self.reassembly:
            return self._build_fused_step(strategy)
        if self._fused_step is None:
            self._fused_step = self._build_fused_step(strategy)
        return self._fused_step

    def _build_contrib_step(self, reassemble: str):
        model = self.model

        def contrib(params, x1, delta_L, gw1, perm):
            # a single contribution's reassembly: order its rows by their
            # virtual-batch positions (``perm`` = within-segment position
            # ranks) — the fused step's reassembly restricted to one
            # segment, through the same strategy.  The tail vjp is row-wise
            # up to the weight-gradient reduction, so this changes the
            # gradient only by summation reassociation (float32 ULPs).
            if reassemble == "pallas":
                from repro.kernels.vb_scatter import scatter_rows
                x1, delta_L = scatter_rows(perm, (x1, delta_L))
            else:
                x1 = jnp.zeros_like(x1).at[perm].set(x1)
                delta_L = jnp.zeros_like(delta_L).at[perm].set(delta_L)
            _, pull = jax.vjp(
                lambda p, h: model.tail_layers(p, h), params, x1)
            g_tail, _ = pull(delta_L)
            return add_first_layer_grads(g_tail, gw1)

        return jax.jit(contrib)

    def _get_contrib_step(self, reassemble: Optional[str] = None):
        """Cached jitted *per-contribution* centralized BP (async TL §3.4):
        tail vjp from one node's payload plus its pruned first-layer leaf
        grads → a full gradient tree, no optimizer.  Shares the fused path's
        compile-once discipline (and its ``reassemble`` strategy — see
        :meth:`_get_fused_step`); ``async_tl`` routes every buffered
        contribution through this instead of an eager ``jax.vjp``.
        Recompiles once per distinct segment length (payloads arrive
        unpadded), which the jit cache absorbs across epochs."""
        strategy = self.reassembly if reassemble is None else reassemble
        if strategy != self.reassembly:
            return self._build_contrib_step(strategy)
        if self._contrib_step is None:
            self._contrib_step = self._build_contrib_step(strategy)
        return self._contrib_step

    def _train_batch_fused(self, vb, results, order) -> StepStats:
        N = vb.size
        segs = [results[nid][0] for nid in order]
        wires = [results[nid][1] for nid in order]
        leaf_idx = self._gw1_leaf_indices()

        # concatenated payloads are exactly (N, ...): one device transfer of
        # the int32 permutation, one scatter dispatch per tensor inside jit
        perm = jnp.asarray(np.concatenate(
            [seg.batch_positions for seg in segs]).astype(np.int32))
        x1_cat = jnp.concatenate([w["x1"] for w in wires])
        dL_cat = jnp.concatenate([w["delta_L"] for w in wires])
        dx1_cat = jnp.concatenate([w["dx1"] for w in wires])
        gw1s = tuple(self._as_leaf_dict(w["gw1"], leaf_idx) for w in wires)

        self.params, self.opt_state, cons = self._get_fused_step()(
            self.params, self.opt_state, x1_cat, dL_cat, dx1_cat, perm, gw1s)

        # loss/accuracy stay device-resident; train_epoch syncs once per epoch
        loss_sum = functools.reduce(operator.add,
                                    [w["loss_sum"] for w in wires])
        n_correct = functools.reduce(operator.add,
                                     [w["n_correct"] for w in wires])
        if not self.check_consistency:
            cons = float("nan")
        return StepStats(loss=loss_sum, acc=n_correct / N,
                         grad_consistency=cons)

    # ------------------------------------------------- eager (reference) path
    def _train_batch_eager(self, vb, results, order) -> StepStats:
        N = vb.size
        # --- reassemble the virtual batch in global shuffled order
        first_seg, first_fp = results[order[0]]
        x1 = jnp.zeros((N,) + first_fp["x1"].shape[1:], first_fp["x1"].dtype)
        dL = jnp.zeros((N,) + first_fp["delta_L"].shape[1:],
                       first_fp["delta_L"].dtype)
        dx1_nodes = jnp.zeros_like(x1)
        leaf_idx = self._gw1_leaf_indices()
        gw1_total: Dict[int, jax.Array] = {}
        loss_sum, n_correct = 0.0, 0
        for nid in order:
            seg, fp = results[nid]
            pos = seg.batch_positions
            x1 = x1.at[pos].set(fp["x1"])
            dL = dL.at[pos].set(fp["delta_L"])
            dx1_nodes = dx1_nodes.at[pos].set(fp["dx1"])
            # accumulate only the leaves first_layer populates — not a full
            # zeros param-pytree per virtual batch
            for i, g in self._as_leaf_dict(fp["gw1"], leaf_idx).items():
                gw1_total[i] = g if i not in gw1_total else gw1_total[i] + g
            loss_sum += fp["loss_sum"] if isinstance(fp["loss_sum"], float) \
                else float(fp["loss_sum"])
            n_correct += fp["n_correct"] if isinstance(fp["n_correct"], int) \
                else int(fp["n_correct"])

        # --- centralized BP: recompute activations from X^(1) (eq. 4–5),
        # backprop from aggregated δ^(L) (eq. 6–11)
        _, pull = jax.vjp(
            lambda p, h: self.model.tail_layers(p, h), self.params, x1)
        g_tail, dx1_orch = pull(dL)
        grads = add_first_layer_grads(g_tail, gw1_total)

        consistency = float(jnp.max(jnp.abs(dx1_orch - dx1_nodes))) \
            if self.check_consistency else float("nan")           # eq. 12

        # --- parameter update (eq. 13–14) + redistribution
        self.params, self.opt_state = self.opt.update(
            self.params, grads, self.opt_state)
        return StepStats(loss=loss_sum, acc=n_correct / N,
                         grad_consistency=consistency)

    # -------------------------------------------------------------- epochs
    def _finalize_epoch_stats(self, stats: List[StepStats]) -> List[StepStats]:
        if self.fused and stats:
            # ONE host sync for the whole epoch's device-resident stats
            vals = jax.device_get([(s.loss, s.acc, s.grad_consistency)
                                   for s in stats])
            stats = [StepStats(loss=float(l), acc=float(a),
                               grad_consistency=float(c))
                     for l, a, c in vals]
        return stats

    def _epoch_batches(self, plan: TraversalPlan, start_batch: int,
                       max_batches: Optional[int]):
        """The slice of this epoch's batches to run, plus whether running
        them completes the epoch (mid-epoch resume/kill support)."""
        if start_batch and self.cache_model_per_epoch:
            raise ValueError(
                "mid-epoch resume (start_batch > 0) is incompatible with "
                "cache_model_per_epoch=True: the nodes' epoch-start "
                "parameters are not recoverable from a step checkpoint")
        stop = (len(plan.batches) if max_batches is None
                else min(len(plan.batches), start_batch + max_batches))
        return plan.batches[start_batch:stop], stop >= len(plan.batches)

    def execute_plan(self, plan: TraversalPlan, *, start_batch: int = 0,
                     max_batches: Optional[int] = None) -> List[StepStats]:
        """Pure executor: run (a slice of) an already-built epoch plan.

        This is the execution half of the planner/executor split — the
        orchestrator never asks where the plan came from, so a nested
        (subtree) plan executes through exactly the same path as a flat
        one.  ``_epoch`` advances only when the epoch's final batch ran."""
        self._active_plan = plan
        batches, completes = self._epoch_batches(plan, start_batch,
                                                 max_batches)
        node_by_id = {n.node_id: n for n in self.nodes}
        if self.cache_model_per_epoch:
            with self.transport.parallel():
                for n in self.nodes:
                    # epoch-start distribution targets the *executor*: an
                    # evicted primary's replica carries its segments now,
                    # and must hold the epoch parameters, not the stale
                    # ones from the failover that evicted the primary
                    self._executor(n.node_id, node_by_id).receive_model(
                        self.transport.send("model", self.params))
        stats = [self.train_batch(vb, node_by_id) for vb in batches]
        if completes:
            self._epoch += 1
        return self._finalize_epoch_stats(stats)

    def train_epoch(self, *, start_batch: int = 0,
                    max_batches: Optional[int] = None) -> List[StepStats]:
        """One epoch (or, for kill/resume, the ``[start_batch, start_batch
        + max_batches)`` slice of one): a thin plan-then-execute shim.
        The virtual-batch plan is a pure function of ``seed + epoch``, so
        a resumed run re-derives exactly the plan the killed run was
        executing and skips the batches whose updates the checkpoint
        already contains."""
        if self.pipelined:
            from repro.core.pipeline import pipelined_train_epoch
            return pipelined_train_epoch(self, start_batch=start_batch,
                                         max_batches=max_batches)
        plan = self.build_plan(self._epoch)
        return self.execute_plan(plan, start_batch=start_batch,
                                 max_batches=max_batches)

    def fit(self, key, epochs: int) -> List[StepStats]:
        if self.params is None:
            self.initialize(key)
        out: List[StepStats] = []
        for _ in range(epochs):
            out.extend(self.train_epoch())
        return out

    # ------------------------------------------------- checkpoint / resume
    @property
    def step(self) -> int:
        """Global virtual-batch counter (checkpoint step index)."""
        return self._step

    def state_dict(self):
        """Everything a killed run needs to resume ULP-identically: the
        parameter/optimizer pytrees plus the traversal cursor (epoch and
        position within it).  The virtual-batch plan itself is *not* stored
        — it is a pure function of ``seed + epoch`` and is re-derived on
        resume, which is what makes mid-epoch recovery exact.  Transport
        byte/clock accounting and node-health eviction state are NOT part
        of the state: a resumed run re-learns them, which changes only the
        audit trail, never the arithmetic."""
        # batches per epoch, computed without touching the transport (a
        # checkpoint must not perturb byte accounting): Algorithm 1 drops
        # the remainder, so every epoch has total_samples // batch_size
        plan_len = max(sum(int(n.x.shape[0]) for n in self.nodes)
                       // self.batch_size, 1)
        return {"arrays": {"params": self.params,
                           "opt_state": self.opt_state},
                "meta": {"epoch": self._epoch, "step": self._step,
                         "batch_in_epoch": self._step % plan_len,
                         "seed": self.seed,
                         "batch_size": self.batch_size}}

    def load_state_dict(self, state) -> int:
        """Restore from :meth:`state_dict`; returns the batch index within
        the current epoch to resume from (pass to ``train_epoch
        (start_batch=...)``)."""
        meta = state["meta"]
        if meta["seed"] != self.seed or meta["batch_size"] != self.batch_size:
            raise ValueError(
                "checkpoint was trained with a different traversal plan "
                f"(seed={meta['seed']}, batch_size={meta['batch_size']}): "
                "resuming would replay different virtual batches")
        self.params = state["arrays"]["params"]
        self.opt_state = state["arrays"]["opt_state"]
        self._epoch = int(meta["epoch"])
        self._step = int(meta["step"])
        return int(meta["batch_in_epoch"])

    def save(self, ckpt_dir: str) -> str:
        """Step-boundary checkpoint via ``repro.checkpoint`` (atomic)."""
        from repro.checkpoint import save_checkpoint
        st = self.state_dict()
        return save_checkpoint(ckpt_dir, self._step, st["arrays"],
                               extra=st["meta"])

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Load the latest (or ``step``'s) checkpoint; returns the
        batch-in-epoch resume cursor.  ``initialize`` must NOT have donated
        params away — restore overwrites whatever is held."""
        from repro.checkpoint import load_checkpoint
        if self.params is None:
            self.initialize(jax.random.PRNGKey(0))     # structure template
        tree = {"params": self.params, "opt_state": self.opt_state}
        arrays, meta = load_checkpoint(ckpt_dir, tree, step)
        return self.load_state_dict(
            {"arrays": arrays, "meta": meta["extra"]})

    # ----------------------------------------------------------- evaluation
    def evaluate(self, x, y):
        logits = self.model.forward(self.params, jnp.asarray(x))
        pred = jnp.argmax(logits, -1)
        return float((pred == jnp.asarray(y)).mean())
