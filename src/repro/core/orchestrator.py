"""TL orchestrator — Algorithm 2: traversal scheduling, activation/gradient
retrieval, centralized BP, model redistribution.

Centralized phase (paper §3.3.2): the orchestrator reassembles the virtual
batch's first-layer activations X^(1) in batch order, *recomputes* all
deeper activations with the current parameters (eq. 4–5), backpropagates
from the aggregated last-layer gradients (eq. 6–11), adds the node-supplied
first-layer weight gradients, applies the update (eq. 13–14), and
redistributes the model.

The orchestrator also verifies eq. 12: its own recomputed ∂L/∂X^(1) must
match the aggregate of the node-submitted first-layer gradients — the
paper's "ensuring consistency with the recalculated forward pass".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.node import TLNode
from repro.core.transport import Transport
from repro.core.virtual_batch import VirtualBatchPlan, create_virtual_batches


@dataclass
class StepStats:
    loss: float
    acc: float
    grad_consistency: float     # max |orchestrator dX1 - aggregated node dX1|


class TLOrchestrator:
    def __init__(self, model, nodes: Sequence[TLNode], optimizer,
                 transport: Optional[Transport] = None, *,
                 batch_size: int = 64, seed: int = 0,
                 compute_time_fn: Callable[[int], float] = lambda n: 0.0,
                 check_consistency: bool = True,
                 cache_model_per_epoch: bool = False):
        self.model = model
        self.nodes = list(nodes)
        self.opt = optimizer
        self.transport = transport or Transport()
        self.batch_size = batch_size
        self.seed = seed
        self.compute_time_fn = compute_time_fn
        self.check_consistency = check_consistency
        # §5.2 caching: redistribute the model once per epoch instead of once
        # per virtual batch (bandwidth optimization; changes staleness!)
        self.cache_model_per_epoch = cache_model_per_epoch
        self.params = None
        self.opt_state = None
        self._epoch = 0

    # ------------------------------------------------------------- lifecycle
    def initialize(self, key):
        self.params = self.model.init(key)
        self.opt_state = self.opt.init(self.params)

    def build_plan(self, epoch: int) -> VirtualBatchPlan:
        ranges = [self.transport.send("index_range", n.index_range())
                  for n in self.nodes]
        return create_virtual_batches(ranges, self.batch_size,
                                      seed=self.seed + epoch)

    # ---------------------------------------------------------- one TL step
    def train_batch(self, vb, node_by_id) -> StepStats:
        N = vb.size
        results, order = {}, []

        if not self.cache_model_per_epoch:
            with self.transport.parallel():
                for seg in vb.traversal:
                    node = node_by_id[seg.node_id]
                    node.receive_model(
                        self.transport.send("model", self.params))

        # --- distributed FP along the traversal plan (pipelined: transfers
        # of one node overlap the next node's compute — paper §3.2)
        with self.transport.parallel():
            for seg in vb.traversal:
                node = node_by_id[seg.node_id]
                self.transport.tick(self.compute_time_fn(len(seg.local_indices)))
                fp = node.forward_visit(seg.local_indices, N)
                wire = self.transport.send(
                    "activations_grads",
                    {"x1": fp.x1, "delta_L": fp.delta_L, "dx1": fp.dx1,
                     "gw1": fp.gw1},
                    compressible=True)
                wire["loss_sum"], wire["n_correct"] = fp.loss_sum, fp.n_correct
                results[seg.node_id] = (seg, wire)
                order.append(seg.node_id)

        # --- reassemble the virtual batch in global shuffled order
        first_seg, first_fp = results[order[0]]
        x1 = jnp.zeros((N,) + first_fp["x1"].shape[1:], first_fp["x1"].dtype)
        dL = jnp.zeros((N,) + first_fp["delta_L"].shape[1:],
                       first_fp["delta_L"].dtype)
        dx1_nodes = jnp.zeros_like(x1)
        gw1_total = jax.tree.map(jnp.zeros_like, self.params)
        loss_sum, n_correct = 0.0, 0
        for nid in order:
            seg, fp = results[nid]
            pos = seg.batch_positions
            x1 = x1.at[pos].set(fp["x1"])
            dL = dL.at[pos].set(fp["delta_L"])
            dx1_nodes = dx1_nodes.at[pos].set(fp["dx1"])
            gw1_total = jax.tree.map(jnp.add, gw1_total, fp["gw1"])
            loss_sum += fp["loss_sum"] if isinstance(fp["loss_sum"], float) \
                else float(fp["loss_sum"])
            n_correct += fp["n_correct"] if isinstance(fp["n_correct"], int) \
                else int(fp["n_correct"])

        # --- centralized BP: recompute activations from X^(1) (eq. 4–5),
        # backprop from aggregated δ^(L) (eq. 6–11)
        _, pull = jax.vjp(
            lambda p, h: self.model.tail_layers(p, h), self.params, x1)
        g_tail, dx1_orch = pull(dL)
        grads = jax.tree.map(jnp.add, g_tail, gw1_total)

        consistency = float(jnp.max(jnp.abs(dx1_orch - dx1_nodes))) \
            if self.check_consistency else float("nan")           # eq. 12

        # --- parameter update (eq. 13–14) + redistribution
        self.params, self.opt_state = self.opt.update(
            self.params, grads, self.opt_state)
        return StepStats(loss=loss_sum, acc=n_correct / N,
                         grad_consistency=consistency)

    # -------------------------------------------------------------- epochs
    def train_epoch(self) -> List[StepStats]:
        plan = self.build_plan(self._epoch)
        node_by_id = {n.node_id: n for n in self.nodes}
        if self.cache_model_per_epoch:
            with self.transport.parallel():
                for n in self.nodes:
                    n.receive_model(self.transport.send("model", self.params))
        stats = [self.train_batch(vb, node_by_id) for vb in plan.batches]
        self._epoch += 1
        return stats

    def fit(self, key, epochs: int) -> List[StepStats]:
        if self.params is None:
            self.initialize(key)
        out: List[StepStats] = []
        for _ in range(epochs):
            out.extend(self.train_epoch())
        return out

    # ----------------------------------------------------------- evaluation
    def evaluate(self, x, y):
        logits = self.model.forward(self.params, jnp.asarray(x))
        pred = jnp.argmax(logits, -1)
        return float((pred == jnp.asarray(y)).mean())
