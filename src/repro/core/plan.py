"""Traversal planning — the planner half of the planner/executor split.

Historically ``TLOrchestrator`` both *planned* an epoch (Algorithm 1:
index-range retrieval, global re-indexing, shuffling, traversal
generation) and *executed* it (Algorithm 2: visits, centralized BP,
update).  This module owns the planning half so the orchestrator can be a
pure executor of a plan it is handed — and so plans can *nest*: a
hierarchical run hands each sub-orchestrator a child plan that covers its
subtree's share of every virtual batch (``repro.core.hierarchy``).

* :class:`TraversalPlan` — an epoch plan: today's :class:`VirtualBatchPlan`
  plus the (seed, epoch) it derives from, the node ids it covers, and the
  per-subtree child plans when the plan is a tree.  It exposes the full
  ``VirtualBatchPlan`` surface (``batches``/``global_to_node``/...) so
  every existing consumer of ``TLOrchestrator.build_plan`` works
  unchanged.
* :class:`Planner` — the protocol: ``plan(ranges, batch_size=, seed=,
  epoch=)``.  Plans must be pure functions of their arguments — the
  checkpoint/resume contract re-derives the plan from ``seed + epoch``.
* :class:`FlatPlanner` — Algorithm 1 verbatim (byte-identical to what
  ``TLOrchestrator.build_plan`` produced before the split; pinned by
  test).
* :class:`TreePlanner` — the same flat *root* plan (this is what keeps the
  hierarchy lossless: the virtual batches, hence the arithmetic, are those
  of the flat run) plus a partition of the nodes into subtrees and one
  child plan per subtree restricting every batch's traversal to that
  subtree's segments.
* :class:`PlanSpec` — the planning knobs (planner, batch size, seed,
  replicas, recovery) grouped into one constructor argument:
  ``TLOrchestrator(..., plan=PlanSpec(...))``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core.virtual_batch import (IndexRange, VirtualBatch,
                                      VirtualBatchPlan,
                                      create_virtual_batches)


@dataclass(frozen=True)
class TraversalPlan:
    """One epoch's traversal plan, possibly a two-tier tree.

    Wraps the :class:`VirtualBatchPlan` Algorithm 1 produces and carries
    the provenance that makes it re-derivable (``seed``, ``epoch``) plus
    the nesting structure (``children``).  A child plan shares the root's
    batches — same ``batch_id``s, same ``global_ids``, same (full) batch
    size, so node-side 1/N loss scaling is untouched — but each batch's
    traversal is restricted to the child's nodes.
    """
    vb_plan: VirtualBatchPlan
    seed: int
    epoch: int
    node_ids: Tuple[int, ...]
    children: Tuple["TraversalPlan", ...] = ()

    # ---- VirtualBatchPlan facade (legacy build_plan consumers) ----------
    @property
    def batches(self) -> Tuple[VirtualBatch, ...]:
        return self.vb_plan.batches

    @property
    def global_to_node(self) -> np.ndarray:
        return self.vb_plan.global_to_node

    @property
    def global_to_local(self) -> np.ndarray:
        return self.vb_plan.global_to_local

    @property
    def n_nodes(self) -> int:
        return self.vb_plan.n_nodes

    @property
    def n_samples(self) -> int:
        return self.vb_plan.n_samples

    # ---- structure ------------------------------------------------------
    def segment_order(self, batch_id: int) -> Tuple[int, ...]:
        """The node-visit order of one batch's traversal."""
        return tuple(s.node_id for s in self.batches[batch_id].traversal)

    def restrict(self, node_ids: Sequence[int]) -> "TraversalPlan":
        """Child plan covering only ``node_ids``: every batch keeps its id,
        global ids and *size* (the 1/N scaling denominator), but its
        traversal drops every other node's segments."""
        keep = frozenset(int(i) for i in node_ids)
        batches = tuple(
            VirtualBatch(batch_id=vb.batch_id, global_ids=vb.global_ids,
                         traversal=tuple(s for s in vb.traversal
                                         if s.node_id in keep))
            for vb in self.batches)
        child_vb = VirtualBatchPlan(
            batches=batches,
            global_to_node=self.vb_plan.global_to_node,
            global_to_local=self.vb_plan.global_to_local,
            n_nodes=len(keep))
        return TraversalPlan(vb_plan=child_vb, seed=self.seed,
                             epoch=self.epoch,
                             node_ids=tuple(sorted(keep)))


@runtime_checkable
class Planner(Protocol):
    """A traversal planner: ranges + (batch_size, seed, epoch) → plan.

    Implementations must be *pure*: the same arguments must yield the same
    plan, because resume/recovery re-derives the plan instead of storing
    it (see ``TLOrchestrator.state_dict``).
    """

    def plan(self, ranges: Sequence[IndexRange], *, batch_size: int,
             seed: int, epoch: int) -> TraversalPlan:
        ...


@dataclass(frozen=True)
class FlatPlanner:
    """Algorithm 1, exactly as the pre-split ``build_plan`` ran it."""

    randomize_ids: bool = False

    def plan(self, ranges: Sequence[IndexRange], *, batch_size: int,
             seed: int, epoch: int) -> TraversalPlan:
        vb_plan = create_virtual_batches(ranges, batch_size,
                                         seed=seed + epoch,
                                         randomize_ids=self.randomize_ids)
        return TraversalPlan(
            vb_plan=vb_plan, seed=seed, epoch=epoch,
            node_ids=tuple(sorted(r.node_id for r in ranges)))


@dataclass(frozen=True)
class TreePlanner:
    """Two-tier plan: the flat root plan + per-subtree child plans.

    The root plan is *identical* to :class:`FlatPlanner`'s — the tree
    changes who executes which segment, never which virtual batches exist
    or where their rows land, which is the whole losslessness argument.
    Nodes are partitioned into ``n_subtrees`` contiguous groups of
    near-equal size (ragged: sizes differ by at most one; a subtree may
    hold a single node; ``n_subtrees`` beyond the node count clamps).
    """

    n_subtrees: int = 2

    def __post_init__(self):
        if self.n_subtrees < 1:
            raise ValueError(f"n_subtrees must be >= 1, "
                             f"got {self.n_subtrees}")

    def partition(self, node_ids: Sequence[int]) -> Tuple[Tuple[int, ...],
                                                          ...]:
        """Exactly-once partition of ``node_ids`` into subtree groups."""
        ids = sorted(int(i) for i in node_ids)
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate node ids")
        k = min(self.n_subtrees, len(ids))
        return tuple(tuple(part.tolist())
                     for part in np.array_split(np.asarray(ids, np.int64), k))

    def plan(self, ranges: Sequence[IndexRange], *, batch_size: int,
             seed: int, epoch: int) -> TraversalPlan:
        root = FlatPlanner().plan(ranges, batch_size=batch_size, seed=seed,
                                  epoch=epoch)
        children = tuple(root.restrict(part)
                         for part in self.partition(root.node_ids))
        return replace(root, children=children)


@dataclass(frozen=True)
class PlanSpec:
    """The orchestrator's planning knobs, grouped into one argument.

    ``batch_size=None`` inherits the orchestrator's ``batch_size``
    constructor argument (the one knob that is also an executor concern —
    checkpoint metadata pins it).  ``planner=None`` means
    :class:`FlatPlanner`.  ``replicas``/``recovery`` configure the
    fault-recovery re-planning machinery (``repro.core.faults``), which is
    a planning concern: failover re-routes a segment without changing the
    plan.
    """

    planner: Optional[Planner] = None
    batch_size: Optional[int] = None
    seed: int = 0
    replicas: Optional[Dict[int, object]] = None
    recovery: Optional[object] = None

    def resolve_planner(self) -> Planner:
        return self.planner if self.planner is not None else FlatPlanner()
