"""Production TL training/serving steps for the multi-pod mesh.

The TPU-native realization of Traversal Learning (DESIGN.md §2):

* the virtual batch shards over the composite (pod, data) mesh axis — one
  shard per logical *node*;
* the node phase computes ``embed → block0`` locally (X^(1) and δ^(L) are
  per-shard values);
* the orchestrator phase is ``jax.checkpoint(tail, policy=nothing_saveable)``
  — the backward pass *recomputes* every activation beyond block 0 from
  X^(1) and the current parameters, exactly the paper's eq. 4–5 recompute,
  then backpropagates (eq. 6–11);
* gradient aggregation across nodes (eq. 6/12) is the psum GSPMD inserts
  for the data-parallel reduce — a single cross-pod collective per step.

``remat_mode`` selects the activation policy:
  "tl"       — paper-faithful: save only X^(1) (+ the node-local embed/block0
               residuals), recompute the whole tail during BP;
  "none"     — beyond-paper baseline: save everything (memory-bound);
  "per_layer"— beyond-paper middle ground: scan-level remat, save each
               cycle's inputs (the usual production policy).

``reassembly`` puts the orchestrator's virtual-batch reassembly on the
production hot path: the loader hands batches node-major (traversal order),
and the centralized phase reassembles X^(1) — and every row-aligned
consumer (targets, MTP tokens, masks) — into shuffled batch order before
the recompute-from-X^(1) BP, exactly the protocol simulator's
``.at[perm].set`` step.  ``"xla"`` uses the generic scatter lowering,
``"pallas"`` the fused ``repro.kernels.vb_scatter`` row-gather kernel
(bit-identical values, one HBM pass, differentiable through the TL loss via
its custom vjp), ``"none"`` skips reassembly (the historical driver).  The
scatter sits behind a ``shard_map`` boundary over the (pod, data) batch
axes: the batch dict's ``perm`` is *shard-local* (each shard's block of
``B/n_dp`` rows holds a permutation of ``0..B/n_dp``, the ranks of that
shard's rows' global batch positions — see ``launch.engine``), so
reassembly adds zero collective traffic at any node count.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.dist.sharding import (batch_axes, param_specs, tokens_pspec,
                                 cache_pspec)
from repro.models import transformer
from repro.models.model import (MTP_WEIGHT, Model, cross_entropy,
                                mtp_shift_targets)


# ------------------------------------------------------------- reassembly

def _make_row_permuter(mesh: Optional[Mesh], strategy: str) -> Callable:
    """Row reassembly ``out[perm[i]] = t[i]`` over batch-leading tensors.

    ``strategy`` selects the lowering ("xla" generic scatter vs "pallas"
    fused vb_scatter kernel).  With a mesh whose (pod, data) axes shard the
    batch, the permutation runs inside a ``shard_map`` over those axes —
    each shard scatters its own rows by its shard-local perm, so the
    reassembly never crosses a chip boundary.  Batches the data axes don't
    divide fall back to a global (replicated) permute, mirroring
    ``tokens_pspec``'s sharding decision for the batch itself.
    """
    def permute(perm, *tensors):
        if strategy == "pallas":
            from repro.kernels.vb_scatter import scatter_rows
            return scatter_rows(perm, tensors)
        return tuple(jnp.zeros_like(t).at[perm].set(t) for t in tensors)

    dp = batch_axes(mesh) if mesh is not None else ()
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if n_dp <= 1:
        return permute

    from jax.experimental.shard_map import shard_map

    def sharded(perm, *tensors):
        B = perm.shape[0]
        if B % n_dp != 0 or B < n_dp:
            return permute(perm, *tensors)
        specs = tuple(P(dp, *([None] * (t.ndim - 1))) for t in tensors)
        return shard_map(permute, mesh=mesh, in_specs=(P(dp),) + specs,
                         out_specs=specs, check_rep=False)(perm, *tensors)

    return sharded


# ------------------------------------------------------------------ TL loss

def tl_loss_fn(model: Model, cfg: ModelConfig, remat_mode: str = "tl",
               reassembly: str = "none", mesh: Optional[Mesh] = None):
    """Loss whose autodiff graph *is* the TL protocol."""
    F = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encdec) else 0
    if reassembly not in ("none", "xla", "pallas"):
        raise ValueError(f"unknown reassembly strategy: {reassembly!r}")

    if cfg.is_encdec:
        if reassembly != "none":
            raise ValueError("reassembly applies to the decoder-LM TL "
                             "split; enc-dec losses take the model.loss "
                             "path")
        # TL boundary for enc-dec: decoder block 0.  The encoder runs in the
        # node phase (it consumes node-local frontend embeddings).
        def loss(params, batch):
            return model.loss(params, batch)[0]
        return loss

    permute_rows = (_make_row_permuter(mesh, reassembly)
                    if reassembly != "none" else None)

    def tail_fn(params, h1, tokens):
        logits, h, aux = transformer.tail(params, cfg, h1, return_hidden=True)
        return logits, h, aux

    if remat_mode == "tl":
        tail_exec = jax.checkpoint(
            tail_fn, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat_mode == "none":
        tail_exec = tail_fn
    elif remat_mode == "dots":
        # beyond-paper middle ground: keep matmul outputs, recompute the rest
        tail_exec = jax.checkpoint(
            tail_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        raise ValueError(remat_mode)

    def loss(params, batch):
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask")
        extra = batch.get("embeds")
        # ---- node phase: first-layer activations X^(1)
        h0 = transformer.embed_tokens(params, cfg, tokens, extra)
        h1, aux0 = transformer.block0(params, cfg, h0)
        if permute_rows is not None:
            # ---- centralized-phase prologue: reassemble the node-major
            # virtual batch into shuffled batch order (shard-local perms,
            # see module docstring) — X^(1) plus every row-aligned consumer
            rows = {"h1": h1, "targets": targets}
            if cfg.mtp_depth:
                rows["tokens"] = tokens
            if mask is not None:
                rows["mask"] = mask
            rows = dict(zip(rows, permute_rows(batch["perm"],
                                               *rows.values())))
            h1, targets = rows["h1"], rows["targets"]
            tokens = rows.get("tokens", tokens)
            mask = rows.get("mask", mask)
        # ---- orchestrator phase: recompute-from-X^(1) BP
        logits, h_final, aux = tail_exec(params, h1, tokens)
        logits_txt = logits[:, F:] if F else logits
        ce = cross_entropy(logits_txt, targets, mask)
        total = ce + aux + aux0
        if cfg.mtp_depth:
            h_txt = h_final[:, F:] if F else h_final
            mtp = transformer.mtp_logits(params, cfg, tokens, h_txt)
            t2, valid = mtp_shift_targets(targets)
            total = total + MTP_WEIGHT * cross_entropy(mtp, t2, valid)
        return total

    return loss


# ------------------------------------------------------------- train step

def make_train_step(model: Model, cfg: ModelConfig, optimizer, *,
                    remat_mode: str = "tl", microbatch: int = 1,
                    reassembly: str = "none",
                    mesh: Optional[Mesh] = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, loss).

    jit/lower with in_shardings from :func:`train_shardings`; GSPMD then
    realizes the TL node axis + orchestrator reduction.

    ``microbatch > 1`` splits the virtual batch into that many sequential
    micro-batches with gradient accumulation (beyond-paper: the update stays
    bit-identical to the full-batch TL update — mean of micro-grads — while
    activation peak memory drops ~microbatch×).

    ``reassembly`` ("none" | "xla" | "pallas") reassembles the virtual
    batch inside the loss (module docstring); the batch dict then carries a
    shard-local ``perm``.  ``mesh`` places the shard_map boundary.
    """
    if reassembly != "none" and microbatch > 1:
        # the perm is defined over the full virtual batch; gradient
        # accumulation slices the batch before reassembly is well-defined
        raise ValueError("reassembly requires microbatch == 1")
    loss_fn = tl_loss_fn(model, cfg, remat_mode, reassembly=reassembly,
                         mesh=mesh)

    if microbatch <= 1:
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = optimizer.update(params, grads, opt_state)
            return params, opt_state, loss
        return step

    def step(params, opt_state, batch):
        def reshape(x):
            return x.reshape((microbatch, x.shape[0] // microbatch)
                             + x.shape[1:])
        micro = {k: reshape(v) for k, v in batch.items()}

        def body(carry, mb):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g, p: (g / microbatch).astype(p.dtype),
                             grads, params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss_sum / microbatch

    return step


def train_shardings(params, opt_state, cfg: ModelConfig, mesh: Mesh,
                    shape: InputShape, *, with_embeds: bool = False,
                    with_perm: bool = False):
    """(in_shardings, out_shardings) pytrees for make_train_step's step.

    ``with_perm=True`` adds the reassembly permutation's spec: ``perm``
    shards with the batch rows (``tokens_pspec``'s batch entry) so each
    shard holds exactly the local perm for its own rows."""
    pspecs = param_specs(params, cfg, mesh)

    # optimizer slots mirror their parameter's sharding rule (paths align
    # because slot trees are tree_map'd off params); scalars replicate
    from repro.dist.sharding import _mesh_sizes, param_pspec
    sizes = _mesh_sizes(mesh)

    def slot_spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        return param_pspec(path, leaf, cfg, axis_sizes=sizes)
    opt_specs = jax.tree_util.tree_map_with_path(slot_spec, opt_state)

    tok_spec = tokens_pspec(mesh, shape.global_batch)
    batch_specs = {"tokens": tok_spec, "targets": tok_spec}
    if with_embeds:
        batch_specs["embeds"] = P(tok_spec[0], None, None)
    if with_perm:
        batch_specs["perm"] = P(tok_spec[0])
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (named(pspecs), named(opt_specs), named(batch_specs))
    out_sh = (named(pspecs), named(opt_specs), NamedSharding(mesh, P()))
    return in_sh, out_sh


# ------------------------------------------------------------- serve step

def make_serve_step(model: Model, cfg: ModelConfig) -> Callable:
    """(params, cache, token, cache_len) -> (logits, cache)."""
    def step(params, cache, token, cache_len):
        return model.decode_step(params, cache, token, cache_len)
    return step


def serve_shardings(params, cache, cfg: ModelConfig, mesh: Mesh,
                    shape: InputShape, *, cache_seq_shard: bool = False,
                    fsdp: Optional[bool] = None):
    """``cache_seq_shard=True`` additionally shards the KV-cache *sequence*
    dim over the ``model`` axis (flash-decoding layout, beyond-paper): each
    model shard owns a contiguous chunk of the context and decode attention
    reduces partial softmax statistics instead of all-gathering the cache.
    ``fsdp=False`` serves with TP-only weight sharding (no per-step weight
    all-gathers)."""
    pspecs = param_specs(params, cfg, mesh, fsdp=fsdp)
    B = shape.global_batch

    def cache_spec(path, leaf):
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        last = name.split("/")[-1]
        nd = leaf.ndim
        # leading stacked-layer axis inside "cycles"/"self" stacks
        lead = 1 if ("cycles" in name or "self" in name) else 0
        core = nd - lead
        if last == "pos":
            return P(*((None,) * nd))
        kind = "state" if last in ("state", "h", "conv", "enc_out") else "kv"
        base = tuple(cache_pspec(mesh, B, kind))
        if cache_seq_shard and kind == "kv":
            # (B, S, ...): batch on dp when divisible; sequence on model
            # (plus dp when the batch dim can't shard, e.g. batch=1)
            if base and base[0] is not None:
                base = (base[0], "model")
            else:
                base = (None, ("model",) + tuple(batch_axes(mesh)))
        spec = list(((None,) * lead + base + (None,) * nd)[:nd])
        # drop axes that don't divide their dim
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
            if ax is None:
                continue
            axes_tuple = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes_tuple:
                size *= mesh.shape[a]
            if dim % size != 0:
                spec[i] = None
        return P(*spec)

    cspecs = jax.tree_util.tree_map_with_path(cache_spec, cache)
    dp = batch_axes(mesh)
    import numpy as np
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    tok_spec = P(dp) if B % n_dp == 0 and B >= n_dp else P()
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (named(pspecs), named(cspecs), named(tok_spec),
             NamedSharding(mesh, P()))
    out_sh = (named(P(dp) if B % n_dp == 0 and B >= n_dp else P()),
              named(cspecs))
    return in_sh, out_sh
