"""Production TL training/serving steps for the multi-pod mesh.

The TPU-native realization of Traversal Learning (DESIGN.md §2):

* the virtual batch shards over the composite (pod, data) mesh axis — one
  shard per logical *node*;
* the node phase computes ``embed → block0`` locally (X^(1) and δ^(L) are
  per-shard values);
* the orchestrator phase is ``jax.checkpoint(tail, policy=nothing_saveable)``
  — the backward pass *recomputes* every activation beyond block 0 from
  X^(1) and the current parameters, exactly the paper's eq. 4–5 recompute,
  then backpropagates (eq. 6–11);
* gradient aggregation across nodes (eq. 6/12) is the psum GSPMD inserts
  for the data-parallel reduce — a single cross-pod collective per step.

``remat_mode`` selects the activation policy:
  "tl"       — paper-faithful: save only X^(1) (+ the node-local embed/block0
               residuals), recompute the whole tail during BP;
  "none"     — beyond-paper baseline: save everything (memory-bound);
  "per_layer"— beyond-paper middle ground: scan-level remat, save each
               cycle's inputs (the usual production policy).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.dist.sharding import (batch_axes, param_specs, tokens_pspec,
                                 cache_pspec)
from repro.models import transformer
from repro.models.model import MTP_WEIGHT, Model, cross_entropy


# ------------------------------------------------------------------ TL loss

def tl_loss_fn(model: Model, cfg: ModelConfig, remat_mode: str = "tl"):
    """Loss whose autodiff graph *is* the TL protocol."""
    F = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encdec) else 0

    if cfg.is_encdec:
        # TL boundary for enc-dec: decoder block 0.  The encoder runs in the
        # node phase (it consumes node-local frontend embeddings).
        def loss(params, batch):
            return model.loss(params, batch)[0]
        return loss

    def tail_fn(params, h1, tokens):
        logits, h, aux = transformer.tail(params, cfg, h1, return_hidden=True)
        return logits, h, aux

    if remat_mode == "tl":
        tail_exec = jax.checkpoint(
            tail_fn, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat_mode == "none":
        tail_exec = tail_fn
    elif remat_mode == "dots":
        # beyond-paper middle ground: keep matmul outputs, recompute the rest
        tail_exec = jax.checkpoint(
            tail_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        raise ValueError(remat_mode)

    def loss(params, batch):
        tokens, targets = batch["tokens"], batch["targets"]
        extra = batch.get("embeds")
        # ---- node phase: first-layer activations X^(1)
        h0 = transformer.embed_tokens(params, cfg, tokens, extra)
        h1, aux0 = transformer.block0(params, cfg, h0)
        # ---- orchestrator phase: recompute-from-X^(1) BP
        logits, h_final, aux = tail_exec(params, h1, tokens)
        logits_txt = logits[:, F:] if F else logits
        ce = cross_entropy(logits_txt, targets, batch.get("mask"))
        total = ce + aux + aux0
        if cfg.mtp_depth:
            h_txt = h_final[:, F:] if F else h_final
            mtp = transformer.mtp_logits(params, cfg, tokens, h_txt)
            t2 = jnp.roll(targets, -1, axis=1)
            valid = jnp.ones_like(t2).at[:, -2:].set(0)
            total = total + MTP_WEIGHT * cross_entropy(mtp, t2, valid)
        return total

    return loss


# ------------------------------------------------------------- train step

def make_train_step(model: Model, cfg: ModelConfig, optimizer, *,
                    remat_mode: str = "tl", microbatch: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, loss).

    jit/lower with in_shardings from :func:`train_shardings`; GSPMD then
    realizes the TL node axis + orchestrator reduction.

    ``microbatch > 1`` splits the virtual batch into that many sequential
    micro-batches with gradient accumulation (beyond-paper: the update stays
    bit-identical to the full-batch TL update — mean of micro-grads — while
    activation peak memory drops ~microbatch×).
    """
    loss_fn = tl_loss_fn(model, cfg, remat_mode)

    if microbatch <= 1:
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = optimizer.update(params, grads, opt_state)
            return params, opt_state, loss
        return step

    def step(params, opt_state, batch):
        def reshape(x):
            return x.reshape((microbatch, x.shape[0] // microbatch)
                             + x.shape[1:])
        micro = {k: reshape(v) for k, v in batch.items()}

        def body(carry, mb):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g, p: (g / microbatch).astype(p.dtype),
                             grads, params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss_sum / microbatch

    return step


def train_shardings(params, opt_state, cfg: ModelConfig, mesh: Mesh,
                    shape: InputShape, *, with_embeds: bool = False):
    """(in_shardings, out_shardings) pytrees for make_train_step's step."""
    pspecs = param_specs(params, cfg, mesh)

    # optimizer slots mirror their parameter's sharding rule (paths align
    # because slot trees are tree_map'd off params); scalars replicate
    from repro.dist.sharding import _mesh_sizes, param_pspec
    sizes = _mesh_sizes(mesh)

    def slot_spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        return param_pspec(path, leaf, cfg, axis_sizes=sizes)
    opt_specs = jax.tree_util.tree_map_with_path(slot_spec, opt_state)

    tok_spec = tokens_pspec(mesh, shape.global_batch)
    batch_specs = {"tokens": tok_spec, "targets": tok_spec}
    if with_embeds:
        batch_specs["embeds"] = P(tok_spec[0], None, None)
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (named(pspecs), named(opt_specs), named(batch_specs))
    out_sh = (named(pspecs), named(opt_specs), NamedSharding(mesh, P()))
    return in_sh, out_sh


# ------------------------------------------------------------- serve step

def make_serve_step(model: Model, cfg: ModelConfig) -> Callable:
    """(params, cache, token, cache_len) -> (logits, cache)."""
    def step(params, cache, token, cache_len):
        return model.decode_step(params, cache, token, cache_len)
    return step


def serve_shardings(params, cache, cfg: ModelConfig, mesh: Mesh,
                    shape: InputShape, *, cache_seq_shard: bool = False,
                    fsdp: Optional[bool] = None):
    """``cache_seq_shard=True`` additionally shards the KV-cache *sequence*
    dim over the ``model`` axis (flash-decoding layout, beyond-paper): each
    model shard owns a contiguous chunk of the context and decode attention
    reduces partial softmax statistics instead of all-gathering the cache.
    ``fsdp=False`` serves with TP-only weight sharding (no per-step weight
    all-gathers)."""
    pspecs = param_specs(params, cfg, mesh, fsdp=fsdp)
    B = shape.global_batch

    def cache_spec(path, leaf):
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        last = name.split("/")[-1]
        nd = leaf.ndim
        # leading stacked-layer axis inside "cycles"/"self" stacks
        lead = 1 if ("cycles" in name or "self" in name) else 0
        core = nd - lead
        if last == "pos":
            return P(*((None,) * nd))
        kind = "state" if last in ("state", "h", "conv", "enc_out") else "kv"
        base = tuple(cache_pspec(mesh, B, kind))
        if cache_seq_shard and kind == "kv":
            # (B, S, ...): batch on dp when divisible; sequence on model
            # (plus dp when the batch dim can't shard, e.g. batch=1)
            if base and base[0] is not None:
                base = (base[0], "model")
            else:
                base = (None, ("model",) + tuple(batch_axes(mesh)))
        spec = list(((None,) * lead + base + (None,) * nd)[:nd])
        # drop axes that don't divide their dim
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
            if ax is None:
                continue
            axes_tuple = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes_tuple:
                size *= mesh.shape[a]
            if dim % size != 0:
                spec[i] = None
        return P(*spec)

    cspecs = jax.tree_util.tree_map_with_path(cache_spec, cache)
    dp = batch_axes(mesh)
    import numpy as np
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    tok_spec = P(dp) if B % n_dp == 0 and B >= n_dp else P()
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (named(pspecs), named(cspecs), named(tok_spec),
             NamedSharding(mesh, P()))
    out_sh = (named(P(dp) if B % n_dp == 0 and B >= n_dp else P()),
              named(cspecs))
    return in_sh, out_sh
