"""Deadline watchdog shared by the elastic trainer and the serving engine.

A hung device call — a collective that never completes on the production
mesh, a decode step that stalls in the serving engine — is invisible to
exception handling: nothing raises, the host just waits forever.  The only
portable detector is a deadline.  :func:`call_with_deadline` runs the
dispatch+sync on a daemon worker thread and raises
:class:`WatchdogTimeout` on the *caller's* thread when the deadline
passes; the worker (the hung call, in the fault model) is left to expire
on its own.  Both supervision loops (``repro.launch.engine`` for training,
``repro.serve.engine`` for serving) catch the timeout and classify it as a
lost device / lost decode step, then run their recovery path.

Extracted from ``repro.launch.elastic`` (PR 6) so the serving robustness
layer can reuse it without importing the training-mesh machinery; the
elastic module re-exports these names unchanged.
"""
from __future__ import annotations

import threading
import time


class WatchdogTimeout(RuntimeError):
    """The supervised call did not complete within its deadline."""


def call_with_deadline(fn, args=(), kwargs=None, *, deadline_s: float,
                       what: str = "step"):
    """Run ``fn(*args, **kwargs)`` under a watchdog deadline.

    The call runs on a daemon worker thread; if it does not finish within
    ``deadline_s`` a :class:`WatchdogTimeout` is raised **on the caller's
    thread** — the worker (a hung collective, in the fault model) is left
    to expire on its own.  Exceptions from ``fn`` re-raise here."""
    if deadline_s <= 0:
        raise ValueError("deadline_s must be > 0")
    box = {}
    done = threading.Event()

    def work():
        try:
            box["value"] = fn(*args, **(kwargs or {}))
        except BaseException as e:  # noqa: BLE001 — re-raised on the caller
            box["error"] = e
        finally:
            done.set()

    threading.Thread(target=work, daemon=True,
                     name=f"tl-watchdog-{what}").start()
    if not done.wait(deadline_s):
        raise WatchdogTimeout(
            f"{what} exceeded its {deadline_s:.1f}s watchdog deadline "
            "(hung collective / lost device)")
    if "error" in box:
        raise box["error"]
    return box["value"]


def simulate_hang(deadline_s: float):
    """Stand-in for a hung collective: sleeps past the watchdog deadline
    (bounded, so the abandoned worker thread eventually exits)."""
    time.sleep(min(3.0 * deadline_s, deadline_s + 30.0))
