"""Hierarchical TL: two-tier orchestration over a tree of sub-executors.

A flat orchestrator's traversal is O(nodes) *sequential* per virtual batch
— node k+1's compute waits on node k's — which caps the reachable node
count.  The hierarchy splits the nodes into subtrees: each subtree runs a
full, lossless inner TL pass over its share of every virtual batch (its
restricted child plan from :class:`~repro.core.plan.TreePlanner`), the
subtrees run concurrently (one transport overlap lane per subtree), and
the root merges per-subtree *gradient contributions* through the same
jitted per-contribution centralized-BP path async TL already uses
(``TLOrchestrator._get_contrib_step`` →
:class:`~repro.core.async_tl.GradientBuffer`), serialized on the root's
clock.

Losslessness: the virtual batches are those of the flat plan (the
TreePlanner's root plan IS the FlatPlanner's), every node still computes
its payload against the full batch size N (child batches keep their
global ids, so the node-side 1/N loss scaling is untouched), and the tail
vjp is row-wise up to the weight-gradient reduction — so the sum of
per-subtree contributions equals the flat full-batch gradient up to f32
summation reassociation.  ``tests/test_hierarchy.py`` pins ULP-equality
against the flat orchestrator on small trees, fused and eager.

Clock model (mirrored analytically by ``runtime_model.runtime_tl`` with
``hierarchy=``): per batch, each subtree's lane carries its model window +
visit window + node compute + subtree-side BP over its rows; the scope
costs the max over lanes; the root merge then charges one serialized
``"contribution"`` transfer per subtree.  Contribution sends happen
*outside* the overlap scope, so merge bytes are counted exactly once in
``bytes_sent`` and appear in no subtree lane's ledger
(``WindowRecord.lane_bytes`` — the per-lane attribution the nested
accounting needs).
"""
from __future__ import annotations

import functools
import operator
from dataclasses import replace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_tl import BufferedContribution, GradientBuffer
from repro.core.node import TLNode, add_first_layer_grads
from repro.core.orchestrator import StepStats, TLOrchestrator
from repro.core.plan import PlanSpec, TreePlanner
from repro.core.transport import Transport
from repro.core.virtual_batch import assert_exactly_once


class HierarchicalOrchestrator(TLOrchestrator):
    """Two-tier TL: subtree executors under a merging root.

    The root owns the (tree) plan, the parameters and the optimizer; each
    subtree is a plain :class:`TLOrchestrator` used purely as an executor
    of restricted child batches — it never plans, never updates, and
    shares the root's transport so all byte/clock accounting lands in one
    ledger.
    """

    def __init__(self, model, nodes: Sequence[TLNode], optimizer,
                 transport: Optional[Transport] = None, *,
                 n_subtrees: int = 2, plan=None, **kwargs):
        if isinstance(plan, PlanSpec):
            spec = plan
        elif plan is None:
            spec = PlanSpec()
        else:
            spec = PlanSpec(planner=plan)
        planner = spec.planner if spec.planner is not None \
            else TreePlanner(n_subtrees)
        if not isinstance(planner, TreePlanner):
            raise ValueError(
                "HierarchicalOrchestrator requires a TreePlanner, got "
                f"{type(planner).__name__}")
        super().__init__(model, nodes, optimizer, transport,
                         plan=replace(spec, planner=planner), **kwargs)
        if self.pipelined:
            raise ValueError(
                "pipelined=True is not supported on the hierarchy: the "
                "subtree lanes already overlap; nest-level double "
                "buffering would double-book the clock")
        if self.donate:
            raise ValueError(
                "donate=True is not supported on the hierarchy: the root "
                "merge path never runs the donating fused step")
        parts = planner.partition([n.node_id for n in self.nodes])
        self.n_subtrees = len(parts)
        self.subtrees = parts
        by_id = {n.node_id: n for n in self.nodes}
        self._subs: List[TLOrchestrator] = []
        for part in parts:
            keep = set(part)
            sub_replicas = {i: r for i, r in self.replicas.items()
                            if i in keep}
            self._subs.append(TLOrchestrator(
                model, [by_id[i] for i in part], optimizer, self.transport,
                plan=PlanSpec(seed=self.seed, batch_size=self.batch_size,
                              replicas=sub_replicas or None,
                              recovery=self.recovery),
                compute_time_fn=self.compute_time_fn,
                bp_time_fn=self.bp_time_fn,
                check_consistency=False,
                cache_model_per_epoch=self.cache_model_per_epoch,
                fused=self.fused, reassembly=self.reassembly))

    # ---------------------------------------------------------- one TL step
    def train_batch(self, vb, node_by_id) -> StepStats:
        plan = self._active_plan
        assert plan is not None and len(plan.children) == len(self._subs), \
            "hierarchical train_batch needs the tree plan execute_plan set"
        tr = self.transport
        contribs = []
        all_segs = []
        with tr.overlap() as scope:
            for i, (sub, child) in enumerate(zip(self._subs, plan.children)):
                cvb = child.batches[vb.batch_id]
                if not cvb.traversal:
                    continue            # subtree absent from this batch
                with scope.lane(f"subtree:{i}"):
                    # the subtree executes against the root's current
                    # parameters and epoch (fault-lane keys match flat's)
                    sub._epoch = self._epoch
                    sub.params = self.params
                    results, order = sub._collect_visits(
                        cvb, {n.node_id: n for n in sub.nodes})
                    segs = [results[nid][0] for nid in order]
                    rows = sum(len(s.local_indices) for s in segs)
                    # subtree-side centralized BP over its rows overlaps
                    # the other subtrees (that division of the BP clock is
                    # the two-tier win)
                    tr.tick(self.bp_time_fn(rows))
                    contribs.append(
                        self._subtree_contribution(i, results, order, rows))
                self.fault_log.extend(sub.fault_log)
                sub.fault_log.clear()
                all_segs.extend(segs)
        # tree-level reassembly invariant: the union of all subtrees'
        # collected segments must partition the full batch exactly once
        assert_exactly_once(vb.size, all_segs)

        # root merge, serialized after the lanes: one contribution upload
        # per subtree through the GradientBuffer path
        buf = GradientBuffer(min_contributions=len(contribs))
        n_correct_total = 0
        for i, grads, loss_sum, n_correct, rows in contribs:
            wire = tr.send("contribution",
                           {"grads": grads,
                            "loss_sum": jnp.asarray(loss_sum, jnp.float32),
                            "n_correct": jnp.asarray(n_correct, jnp.int32)})
            buf.add(BufferedContribution(
                node_id=i, model_version=self._step,
                grads=wire["grads"], loss_sum=wire["loss_sum"],
                n_samples=rows), self._step)
            n_correct_total = n_correct_total + wire["n_correct"]
        assert buf.ready()
        grads, loss_sum, n_rows = buf.drain()
        assert n_rows == vb.size
        self._step += 1
        # contributions are pre-scaled by 1/N on the nodes, so the drained
        # sum is the flat full-batch gradient (eq. 13–14 update unchanged)
        self.params, self.opt_state = self.opt.update(
            self.params, grads, self.opt_state)
        return StepStats(loss=loss_sum, acc=n_correct_total / vb.size,
                         grad_consistency=float("nan"))

    def _subtree_contribution(self, i, results, order, rows):
        """One subtree's gradient contribution: its rows' centralized BP
        through the cached jitted per-contribution step (fused) or the
        eager oracle vjp — exactly the async-TL §3.4 path, applied to a
        whole subtree's concatenated segments instead of one node's."""
        segs = [results[nid][0] for nid in order]
        wires = [results[nid][1] for nid in order]
        leaf_idx = self._gw1_leaf_indices()
        gw1 = {}
        for w in wires:
            for k, g in self._as_leaf_dict(w["gw1"], leaf_idx).items():
                gw1[k] = g if k not in gw1 else gw1[k] + g
        pos = np.concatenate([s.batch_positions for s in segs])
        ranks = np.argsort(np.argsort(pos)).astype(np.int32)
        x1 = jnp.concatenate([w["x1"] for w in wires])
        dL = jnp.concatenate([w["delta_L"] for w in wires])
        if self.fused:
            grads = self._get_contrib_step()(
                self.params, x1, dL, gw1, jnp.asarray(ranks))
        else:
            x1o = jnp.zeros_like(x1).at[ranks].set(x1)
            dLo = jnp.zeros_like(dL).at[ranks].set(dL)
            _, pull = jax.vjp(
                lambda p, h: self.model.tail_layers(p, h), self.params, x1o)
            g_tail, _ = pull(dLo)
            grads = add_first_layer_grads(g_tail, gw1)
        loss_sum = functools.reduce(operator.add,
                                    [w["loss_sum"] for w in wires])
        n_correct = functools.reduce(operator.add,
                                     [w["n_correct"] for w in wires])
        return i, grads, loss_sum, n_correct, rows
