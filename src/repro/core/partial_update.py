"""Paper §5.1: partial parameter update transfer.

When redistributing the model, transmit only the parameters whose change
exceeds a threshold (or the top-k fraction by |Δ|), plus their indices —
the paper's answer to O(model) redistribution cost as models grow.  The
receiving node patches its cached copy.  Lossy only in what it *delays*:
untransmitted deltas accumulate orchestrator-side and ship once they cross
the threshold, so drift is bounded by ``threshold`` per weight.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PartialUpdateCodec:
    """Orchestrator-side encoder with per-leaf residual accumulation."""

    threshold: float = 0.0        # absolute |Δ| threshold
    top_frac: Optional[float] = None   # or: ship the top fraction by |Δ|
    _residual: Optional[object] = None  # un-shipped deltas
    bytes_full: int = 0
    bytes_sent: int = 0

    def encode(self, old_params, new_params):
        """Returns a payload {leaf_idx: (flat_indices, values)}."""
        leaves_old, treedef = jax.tree.flatten(old_params)
        leaves_new = jax.tree.leaves(new_params)
        if self._residual is None:
            self._residual = [jnp.zeros_like(l) for l in leaves_old]
        payload = {}
        for i, (lo, ln) in enumerate(zip(leaves_old, leaves_new)):
            delta = (ln - lo) + self._residual[i]
            flat = delta.ravel()
            self.bytes_full += int(flat.nbytes)
            if self.top_frac is not None:
                k = max(1, int(flat.size * self.top_frac))
                idx = jnp.argsort(-jnp.abs(flat))[:k]
                mask = jnp.zeros_like(flat, jnp.bool_).at[idx].set(True)
            else:
                mask = jnp.abs(flat) > self.threshold
            idx = np.nonzero(np.asarray(mask))[0]
            vals = np.asarray(flat)[idx]
            payload[i] = (idx.astype(np.int32), vals)
            self.bytes_sent += int(idx.nbytes + vals.nbytes)
            # what we did not ship stays in the residual
            kept = jnp.asarray(np.asarray(flat) * ~np.asarray(mask))
            self._residual[i] = kept.reshape(lo.shape)
        return payload, treedef

    @staticmethod
    def apply(cached_params, payload_treedef) -> object:
        """Node-side: patch a cached param copy with a partial update."""
        payload, treedef = payload_treedef
        leaves = list(jax.tree.leaves(cached_params))
        for i, (idx, vals) in payload.items():
            flat = np.array(leaves[i], copy=True).ravel()
            flat[idx] = flat[idx] + vals
            leaves[i] = jnp.asarray(flat.reshape(leaves[i].shape))
        return jax.tree.unflatten(treedef, leaves)

    @property
    def compression_ratio(self) -> float:
        return self.bytes_full / max(self.bytes_sent, 1)
