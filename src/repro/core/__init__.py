"""The paper's contribution: Traversal Learning.

* ``virtual_batch``  — Algorithm 1 (index retrieval, global re-indexing,
                       shuffling, traversal plan)
* ``plan``           — traversal planning (planner/executor split):
                       ``TraversalPlan``, ``Planner`` protocol,
                       ``FlatPlanner`` / ``TreePlanner``, ``PlanSpec``
* ``node`` / ``orchestrator`` — Algorithm 2 protocol over a byte-accounting
                       ``transport``; the orchestrator executes plans
* ``hierarchy``      — two-tier orchestration: subtree executors under a
                       contribution-merging root (lossless)
* ``baselines``      — CL / FL (FedAvg) / SL / SL+ / SFL comparison methods
* ``pipeline``       — double-buffered epoch engine (cross-batch overlap of
                       node visits with centralized BP; lossless reordering)
* ``tl_step``        — production pjit TL train/serve steps (multi-pod)
* ``runtime_model``  — analytic runtime, paper eqs. (15)-(19)
"""
from repro.core.hierarchy import HierarchicalOrchestrator
from repro.core.node import TLNode
from repro.core.orchestrator import TLOrchestrator
from repro.core.pipeline import PipelinedEpochEngine, pipelined_train_epoch
from repro.core.plan import (FlatPlanner, Planner, PlanSpec, TraversalPlan,
                             TreePlanner)
from repro.core.transport import NetworkModel, Transport, payload_bytes
from repro.core.virtual_batch import (IndexRange, VirtualBatch,
                                      VirtualBatchPlan, create_virtual_batches)

__all__ = ["TLNode", "TLOrchestrator", "HierarchicalOrchestrator",
           "NetworkModel", "Transport", "payload_bytes", "IndexRange",
           "VirtualBatch", "VirtualBatchPlan", "create_virtual_batches",
           "PipelinedEpochEngine", "pipelined_train_epoch", "TraversalPlan",
           "Planner", "PlanSpec", "FlatPlanner", "TreePlanner"]
