"""Analytic runtime model — paper eqs. (15)–(19).

    T_FL  = max(T_comp,client) + T_comm + T_agg                    (15)
    T_SL  = Σ_clients (T_comp,client + 2 T_comm) + T_comp,server   (16)
    T_SL+ = Σ_clients (T_comp,client^{more layers} + 2 T_comm) + T_comp,server
    T_SFL = max(T_comp,client + T_comm) + T_agg                    (18)
    T_TL  = max(T_comp,client) + T_comm + T_comp,server            (19)

Communication volumes per method (per round over n nodes):
    FL  : 2 · |θ| · n                       (model down + update up)
    SL  : 2 · |X^(1)| per batch (sequential)
    SFL : 2 · |θ_client| · n + 2 · |X^(1)|
    TL  : |X^(1)| + |∂X^(1)| + |δ^(L)| + |∂W^(1)|  (+ model distribution)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    n_nodes: int
    samples_per_node: int
    batch_size: int
    model_bytes: int
    first_layer_bytes_per_sample: int     # X^(1) row size
    logits_bytes_per_sample: int          # δ^(L) row size
    first_layer_param_bytes: int
    flops_per_sample_fwd: float
    flops_per_sample_bwd: float
    client_flops_per_s: float = 1e12
    server_flops_per_s: float = 1e13
    bandwidth_bytes_per_s: float = 1e9 / 8
    rtt_s: float = 0.02
    agg_s: float = 0.05


def _t_comm(spec: WorkloadSpec, nbytes: float) -> float:
    return spec.rtt_s + nbytes / spec.bandwidth_bytes_per_s


def _per_round(spec: WorkloadSpec):
    n_batches = spec.n_nodes * spec.samples_per_node // spec.batch_size
    samples_client = spec.samples_per_node
    t_fwd = spec.flops_per_sample_fwd / spec.client_flops_per_s
    t_bwd = spec.flops_per_sample_bwd / spec.client_flops_per_s
    return n_batches, samples_client, t_fwd, t_bwd


def runtime_fl(spec: WorkloadSpec, local_epochs: int = 1) -> float:
    _, samples, t_fwd, t_bwd = _per_round(spec)
    t_client = local_epochs * samples * (t_fwd + t_bwd)
    t_comm = _t_comm(spec, 2 * spec.model_bytes)
    return t_client + t_comm + spec.agg_s                               # (15)


def runtime_sl(spec: WorkloadSpec, extra_client_layers: float = 0.0) -> float:
    _, samples, t_fwd, t_bwd = _per_round(spec)
    act_bytes = spec.batch_size * spec.first_layer_bytes_per_sample
    n_local_batches = samples // spec.batch_size
    t_client = samples * (t_fwd + t_bwd) * (0.3 + extra_client_layers)
    t_server = samples * (t_fwd + t_bwd) * 0.7 \
        * spec.client_flops_per_s / spec.server_flops_per_s
    per_client = t_client + n_local_batches * 2 * _t_comm(spec, act_bytes) + t_server
    return spec.n_nodes * per_client                                    # (16) sequential


def runtime_slp(spec: WorkloadSpec) -> float:
    return runtime_sl(spec, extra_client_layers=0.15)                   # (17)


def runtime_sfl(spec: WorkloadSpec) -> float:
    _, samples, t_fwd, t_bwd = _per_round(spec)
    act_bytes = spec.batch_size * spec.first_layer_bytes_per_sample
    n_local_batches = samples // spec.batch_size
    client_model = 0.3 * spec.model_bytes
    t_client = samples * (t_fwd + t_bwd) * 0.3 \
        + n_local_batches * 2 * _t_comm(spec, act_bytes) \
        + _t_comm(spec, 2 * client_model)
    return t_client + spec.agg_s                                        # (18) max over equal clients


def _runtime_tl_tree(spec: WorkloadSpec, n_subtrees: int) -> float:
    """Eq. 19, two-tier branch: the transport-composition clock of a
    hierarchical (or, at ``n_subtrees=1``, flat serial) TL epoch.

    Mirrors ``repro.core.hierarchy`` term by term under the *uniform
    composition* assumption — every node contributes ``batch_size /
    n_nodes`` rows to every virtual batch (exact when one batch spans the
    whole dataset, the regime the node-count benchmark runs in):

    * per subtree lane: model-redistribution window (max over identical
      transfers = one) + visit window (ditto) + the subtree's serial node
      compute + its share of the centralized BP;
    * inner traversals run in parallel lanes → max over subtrees;
    * the root merge is serialized: one ``contribution`` upload (gradient
      pytree = ``model_bytes``, + 8 B of stats scalars) per subtree;
    * plus the epoch's plan cost (one index-range RTT per node).

    Byte terms reproduce the simulator's wire format exactly (visit rows,
    pruned first-layer grads, 8 B stats scalars per visit), so at
    ``rtt_s=0``-style configurations the prediction matches the measured
    transport clock to float tolerance (see the eq. 19 alignment tests).
    """
    if n_subtrees < 1:
        raise ValueError(f"n_subtrees must be >= 1, got {n_subtrees}")
    n = spec.n_nodes
    if spec.batch_size % n:
        raise ValueError(
            "two-tier branch assumes uniform batch composition: "
            f"batch_size ({spec.batch_size}) must be a multiple of "
            f"n_nodes ({n})")
    rows_per_node = spec.batch_size // n
    n_batches = max(n * spec.samples_per_node // spec.batch_size, 1)
    t_fb = (spec.flops_per_sample_fwd + spec.flops_per_sample_bwd) \
        / spec.client_flops_per_s
    bp_per_sample = t_fb * spec.client_flops_per_s / spec.server_flops_per_s
    seg_bytes = (rows_per_node * (2 * spec.first_layer_bytes_per_sample
                                  + spec.logits_bytes_per_sample)
                 + spec.first_layer_param_bytes + 8)
    model_t = _t_comm(spec, spec.model_bytes)
    visit_t = _t_comm(spec, seg_bytes)
    sizes = [len(part) for part in
             np.array_split(np.arange(n), min(n_subtrees, n))]
    lanes = [model_t + visit_t
             + m * rows_per_node * (t_fb + bp_per_sample) for m in sizes]
    per_batch = max(lanes)
    if n_subtrees > 1:
        per_batch += len(sizes) * _t_comm(spec, spec.model_bytes + 8)
    return n * spec.rtt_s + n_batches * per_batch


def runtime_tl(spec: WorkloadSpec, *, compressed: bool = False,
               cache_model: bool = False, pipelined: bool = True,
               drop_prob: float = 0.0, straggle_prob: float = 0.0,
               straggle_factor: float = 1.0,
               hierarchy: int | None = None) -> float:
    """Eq. 19, optionally with the double-buffered cross-batch pipeline.

    ``pipelined=True`` mirrors the epoch engine (``repro.core.pipeline``):
    batch k+1's visit production overlaps batch k's centralized BP, so the
    per-epoch time is pipeline-fill + (n_batches - 1) steady-state stages of
    ``max(producer, consumer)`` + drain — the shape the transport's overlap
    windows make *measurable* in the protocol simulator, not just analytic.
    With ``cache_model=True`` the whole visit (client compute + transfers)
    rides the overlap; in strict mode only the transfers do (client compute
    must wait for the updated parameters).

    The fault knobs mirror ``repro.core.faults.FaultSpec``: the visit phase
    (client compute + wire) is expanded by the expected retry/straggle
    multiplier :func:`repro.core.faults.fault_expansion` — geometric
    retries under ``drop_prob``, expected slowdown under ``straggle_prob``
    × ``straggle_factor`` — so the analytic clock stays comparable to the
    fault-injected transport-simulated clock.  The orchestrator's
    centralized BP is unaffected (faults live on the node/wire side), and
    losslessness means the *arithmetic* is unchanged either way: only time
    expands.

    ``hierarchy=s`` routes to the two-tier branch (:func:`_runtime_tl_tree`):
    the clock of ``s`` subtree lanes running inner traversals in parallel
    with a serialized root merge (``s=1``: the flat serial window clock of
    the same composition — the baseline the hierarchy divides).  The
    branch is exact per transport composition rather than the aggregate
    eq. 19 approximation, and is incompatible with the other knobs."""
    if hierarchy is not None:
        # cross-batch pipelining does not apply (the subtree lanes are the
        # overlap); ``pipelined`` is ignored rather than required off
        if compressed or cache_model or drop_prob or straggle_prob:
            raise ValueError(
                "hierarchy= models the plain (uncompressed, uncached) "
                "two-tier clock; other knobs are unsupported")
        return _runtime_tl_tree(spec, hierarchy)
    from repro.core.faults import fault_expansion
    expansion = fault_expansion(drop_prob, straggle_prob, straggle_factor)
    _, samples, t_fwd, t_bwd = _per_round(spec)
    n_local_batches = samples // spec.batch_size
    # client computes FP + local BP for the three gradients; under faults
    # the whole visit phase (compute + its wire, below) expands by the
    # expected number of attempts × expected straggle factor
    t_client = samples * (t_fwd + t_bwd) * expansion
    per_sample_wire = (2 * spec.first_layer_bytes_per_sample
                       + spec.logits_bytes_per_sample)
    wire = samples * per_sample_wire + n_local_batches * spec.first_layer_param_bytes
    if compressed:
        # act_compress wire format (§5.2): 1 B/element + one 4 B f32 scale
        # per row (``act_compress.compressed_bytes``).  The f32 element
        # count is wire/4; rows are X^(1), ∂X^(1), δ^(L) — one per sample
        # each — plus ∂W^(1)'s first_layer_param_bytes /
        # first_layer_bytes_per_sample rows per local batch (D_in + 1 for
        # a dense first layer: weight rows + the bias row)
        rows = (3 * samples + n_local_batches * spec.first_layer_param_bytes
                / spec.first_layer_bytes_per_sample)
        wire = wire / 4 + 4 * rows
    total_wire = wire
    if not cache_model:
        total_wire += n_local_batches * spec.model_bytes   # redistribution
    # only the visit-payload wire is subject to retries/straggle — model
    # redistribution rides outside the fault lanes in the simulator (a
    # failover re-send is second-order).  The expansion adds pure transfer
    # time of the retried visit wire on top of the unchanged fault-free
    # eq. 19 term (one RTT per round, as before), so the pre-existing
    # analytic baseline is bit-identical when the fault knobs are off
    t_comm = (_t_comm(spec, total_wire)
              + (expansion - 1.0) * wire / spec.bandwidth_bytes_per_s)
    # orchestrator recompute + BP on the full virtual batch
    t_server = (samples * spec.n_nodes * (t_fwd + t_bwd)
                * spec.client_flops_per_s / spec.server_flops_per_s)
    if not pipelined:
        return t_client + t_comm + t_server                             # (19)
    n_batches = max(n_local_batches * spec.n_nodes, 1)
    t_sb = t_server / n_batches                     # consumer stage (BP of k)
    if cache_model:
        # visits of k+1 are update-independent: whole producer overlaps
        t_vb = (t_client + t_comm) / n_batches
        return t_vb + (n_batches - 1) * max(t_vb, t_sb) + t_sb
    # strict: transfers of k+1 overlap BP of k, client compute stays serial
    t_cb = t_comm / n_batches
    return t_client + t_cb + (n_batches - 1) * max(t_cb, t_sb) + t_sb


def recovery_cost(step_time_s: float, rollback_depth: int, rejit_s: float,
                  *, restore_s: float = 0.0, detect_s: float = 0.0,
                  replay_s: float = 0.0) -> float:
    """Wall-clock cost of one elastic device-loss recovery.

    The elastic engine (``repro.launch.engine``) pays, per recovery:
    re-running the ``rollback_depth`` steps lost since the newest
    checkpoint (at the steady-state ``step_time_s`` clock), re-jitting the
    step for the reshrunk mesh (``rejit_s``, the dominant fixed cost), and
    the smaller detect/restore/replay terms its :class:`~repro.launch
    .elastic.RecoveryReport` measures.  Depth is bounded by
    ``ckpt_every - 1``, which is the knob this term exists to size: the
    checkpoint cadence trades steady-state save overhead against
    per-recovery replay."""
    if rollback_depth < 0:
        raise ValueError("rollback_depth must be >= 0")
    return (rollback_depth * step_time_s + rejit_s + restore_s + detect_s
            + replay_s)


def expected_recovery_overhead(step_time_s: float, *, loss_prob: float,
                               ckpt_every: int, rejit_s: float,
                               restore_s: float = 0.0) -> float:
    """Expected per-step overhead of elastic recovery under a per-step
    device-loss probability.

    Each step loses a device with probability ``loss_prob``; the expected
    rollback depth at a uniformly-random loss point is
    ``(ckpt_every - 1) / 2``.  Returns seconds of expected extra wall-clock
    per step — add to the eq. 15-19 step clock for a fault-adjusted
    projection (the chip-fault analogue of ``fault_expansion``'s WAN
    term)."""
    if not 0.0 <= loss_prob < 1.0:
        raise ValueError("loss_prob must be in [0, 1)")
    if ckpt_every < 1:
        raise ValueError("ckpt_every must be >= 1")
    mean_depth = (ckpt_every - 1) / 2.0
    per_recovery = recovery_cost(step_time_s, 0, rejit_s,
                                 restore_s=restore_s) \
        + mean_depth * step_time_s
    return loss_prob * per_recovery


ALL = {"FL": runtime_fl, "SL": runtime_sl, "SL+": runtime_slp,
       "SFL": runtime_sfl, "TL": runtime_tl}
