"""TL node: owns a private data shard, performs distributed-phase FP.

Per paper §3.3.1 a node, given the current model:
  1. computes first-layer activations X^(1) for its slice of the virtual
     batch (eq. 1–2),
  2. runs the full forward locally and local BP to obtain the last-layer
     gradient δ^(L) (eq. 3) and the first-layer gradient ∂L/∂X^(1),
  3. transmits only {X^(1), ∂L/∂X^(1), δ^(L)} to the orchestrator — never
     raw data or labels.

Completion of an under-specified point (recorded in DESIGN.md): the paper's
eqs. 7–11 update layers L..2 from recomputed activations but give no
∂L/∂W^(1) — which cannot be formed without the raw inputs x.  The only
privacy-preserving completion is for the node to also send its *first-layer
weight gradients* (a single layer's worth of parameters), computed during
the same local BP.  With that, TL's global update is exactly the CL update.

Performance: by default the whole visit (first layer + local BP) runs as a
single jitted computation per segment shape (``jit_visits=True``), with the
loss/accuracy statistics kept device-resident — the orchestrator syncs them
to the host once per epoch, not once per visit.  ``jit_visits=False``
recovers the original eager op-by-op reference path (used as the benchmark
baseline).  The shipped first-layer weight gradients are *pruned* to the
leaves ``first_layer`` actually reads (see :func:`first_layer_grad_leaves`);
the rest of the tree is structurally zero and is never materialized or sent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def ce_sum(logits, y):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1).sum()


def _bucket(k: int, minimum: int = 8) -> int:
    """Next power of two >= k (>= minimum): visits are padded to bucket
    sizes so the jitted visit compiles O(log max_segment) times total
    instead of once per distinct traversal-segment length."""
    b = minimum
    while b < k:
        b *= 2
    return b


def first_layer_grad_leaves(model, params, x_sample) -> tuple:
    """Indices (in ``params`` flatten order) of the leaves
    ``model.first_layer`` actually reads.

    Determined structurally by tracing the jaxpr and collecting which input
    vars feed any equation — every other leaf's first-layer weight gradient
    is a structural zero the node need not compute, ship, or accumulate.
    """
    from jax.extend.core import Var

    flat, treedef = jax.tree_util.tree_flatten(params)

    def fn(leaves, x):
        return model.first_layer(jax.tree_util.tree_unflatten(treedef, leaves), x)

    closed = jax.make_jaxpr(fn)(flat, x_sample)
    used = set()
    for eqn in closed.jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, Var):
                used.add(v)
    for v in closed.jaxpr.outvars:
        if isinstance(v, Var):
            used.add(v)
    return tuple(i for i, v in enumerate(closed.jaxpr.invars[:len(flat)])
                 if v in used)


def add_first_layer_grads(grads, gw1):
    """Add node-supplied first-layer weight grads into a full gradient tree.

    ``gw1`` is either a pruned ``{leaf_index: array}`` dict (jitted nodes) or
    a full params-shaped pytree (eager reference nodes).
    """
    if isinstance(gw1, dict) and all(isinstance(k, int) for k in gw1):
        flat, treedef = jax.tree_util.tree_flatten(grads)
        for i, g in gw1.items():
            flat[i] = flat[i] + g
        return jax.tree_util.tree_unflatten(treedef, flat)
    return jax.tree.map(jnp.add, grads, gw1)


# One compiled visit per *model* (not per node): every node holding the same
# model instance shares the jit cache, so n_nodes × n_buckets compiles
# collapse to n_buckets.  The cache lives ON the model object (the jitted
# closure references the model, so any external model-keyed map — weak or
# not — would pin the model and its executables for the process lifetime).
_VISIT_CACHE_ATTR = "_tl_visit_cache"


def _get_visit_fn(model, params, x_sample):
    """(keep_leaf_indices, jitted visit) for ``model``, built once.

    The visit runs the whole node phase — first layer, local BP for δ^(L),
    ∂L/∂X^(1) and the pruned first-layer weight grads — as one compiled
    function over a *padded* segment: ``mask`` marks the real rows, padded
    rows carry zero cotangents so they contribute exactly zero to every
    gradient, and the loss/accuracy sums come back as device scalars.
    """
    cached = getattr(model, _VISIT_CACHE_ATTR, None)
    if cached is not None:
        return cached
    keep = first_layer_grad_leaves(model, params, x_sample)

    def visit(params, xb, yb, mask, batch_total):
        x1 = model.first_layer(params, xb)                         # eq. 1–2
        logits, pull_tail = jax.vjp(
            lambda h: model.tail_layers(params, h), x1)

        def masked_loss(lg):
            logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, yb[:, None], axis=-1)[:, 0]
            return (nll * mask).sum() / batch_total

        loss = masked_loss(logits)
        delta_L = jax.grad(masked_loss)(logits)                    # eq. 3
        (dx1,) = pull_tail(delta_L)
        _, pull_first = jax.vjp(lambda p: model.first_layer(p, xb), params)
        (gw1,) = pull_first(dx1)
        gw1_flat = jax.tree_util.tree_leaves(gw1)
        acc = ((jnp.argmax(logits, -1) == yb) & (mask > 0)).sum()
        # only the structurally-nonzero leaves survive; XLA DCEs the rest
        return (x1, delta_L, dx1, tuple(gw1_flat[i] for i in keep),
                loss, acc)

    cached = (keep, jax.jit(visit, static_argnums=(4,)))
    try:
        setattr(model, _VISIT_CACHE_ATTR, cached)
    except AttributeError:                     # frozen dataclass facade
        object.__setattr__(model, _VISIT_CACHE_ATTR, cached)
    return cached


@dataclass
class FPResult:
    """What a node ships to the orchestrator after its FP visit."""
    x1: Any                 # first-layer activations, (k, ...)
    delta_L: Any            # last-layer gradients dL/dlogits, (k, C)
    dx1: Any                # first-layer gradients dL/dX^(1), (k, ...)
    gw1: Any                # first-layer weight grads: pruned {leaf_idx: arr}
                            # (jitted) or a full param pytree (eager)
    loss_sum: Any           # device scalar (jitted) or float (eager)
    n_correct: Any          # device scalar (jitted) or int (eager)


class TLNode:
    """Holds a private shard (x, y); executes FP visits."""

    def __init__(self, node_id: int, model, x, y, *, jit_visits: bool = True):
        self.node_id = node_id
        self.model = model
        self.x = jnp.asarray(x)
        self.y = jnp.asarray(y)
        self.params = None          # set by orchestrator's model distribution
        self.jit_visits = jit_visits
        self._visit_fn = None       # built lazily (needs params for tracing)
        self._gw1_leaves = None

    # ---- protocol surface --------------------------------------------------
    def index_range(self):
        from repro.core.virtual_batch import IndexRange
        return IndexRange(self.node_id, int(self.x.shape[0]))

    def receive_model(self, params):
        self.params = params

    def issue_visit(self, local_indices: np.ndarray,
                    batch_total: int) -> FPResult:
        """Issue a visit without forcing any host synchronization.

        Identical math to :meth:`forward_visit`, but the eager reference
        path keeps ``loss_sum``/``n_correct`` as device scalars instead of
        converting them with ``float()``/``int()`` (which blocks on the
        device).  The pipelined epoch engine uses this so *producing* batch
        k+1's payloads never materializes — and therefore never waits on —
        batch k's in-flight centralized BP; consumers materialize lazily.
        """
        return self.forward_visit(local_indices, batch_total,
                                  materialize=False)

    def forward_visit(self, local_indices: np.ndarray, batch_total: int,
                      *, materialize: bool = True) -> FPResult:
        """One node visit of the traversal plan.  ``batch_total`` is the full
        virtual-batch size N so the node scales its loss to (1/N)·Σ local CE,
        making orchestrator-side aggregation a plain sum (exact CL grads for
        unequal node shares — paper eq. 6 assumes equal shares)."""
        assert self.params is not None, "model not distributed to node"
        xb = self.x[local_indices]
        yb = self.y[local_indices]
        if not self.jit_visits:
            return self._visit_eager(xb, yb, batch_total,
                                     materialize=materialize)
        if self._visit_fn is None:
            self._gw1_leaves, self._visit_fn = _get_visit_fn(
                self.model, self.params, xb)
        k = xb.shape[0]
        b = _bucket(k)
        if b != k:                 # pad to the bucket; mask marks real rows
            pad = [(0, b - k)] + [(0, 0)] * (xb.ndim - 1)
            xb = jnp.pad(xb, pad)
            yb = jnp.pad(yb, (0, b - k))
        mask = (jnp.arange(b) < k).astype(jnp.float32)
        x1, delta_L, dx1, gw1, loss, acc = self._visit_fn(
            self.params, xb, yb, mask, batch_total)
        if b != k:                 # ship only the real rows
            x1, delta_L, dx1 = x1[:k], delta_L[:k], dx1[:k]
        return FPResult(x1=x1, delta_L=delta_L, dx1=dx1,
                        gw1=dict(zip(self._gw1_leaves, gw1)),
                        loss_sum=loss, n_correct=acc)

    def _visit_eager(self, xb, yb, batch_total: int,
                     *, materialize: bool = True) -> FPResult:
        """The original op-by-op reference visit (full gw1 tree, host-synced
        stats); kept as the benchmark baseline and equivalence oracle.
        ``materialize=False`` defers the loss/accuracy host sync (device
        scalars are shipped instead — the orchestrator's accumulation
        handles both)."""
        m, params = self.model, self.params
        x1 = m.first_layer(params, xb)                                 # eq. 1–2
        logits, pull_tail = jax.vjp(lambda h: m.tail_layers(params, h), x1)
        loss = ce_sum(logits, yb) / batch_total
        delta_L = jax.grad(lambda lg: ce_sum(lg, yb) / batch_total)(logits)  # eq. 3
        (dx1,) = pull_tail(delta_L)
        _, pull_first = jax.vjp(lambda p: m.first_layer(p, xb), params)
        (gw1,) = pull_first(dx1)
        acc = (jnp.argmax(logits, -1) == yb).sum()
        return FPResult(x1=x1, delta_L=delta_L, dx1=dx1, gw1=gw1,
                        loss_sum=float(loss) if materialize else loss,
                        n_correct=int(acc) if materialize else acc)

    # ---- local evaluation (inference stays on-node) -------------------------
    def evaluate(self, params):
        logits = self.model.forward(params, self.x)
        loss = float(ce_sum(logits, self.y) / self.x.shape[0])
        acc = float((jnp.argmax(logits, -1) == self.y).mean())
        return {"loss": loss, "acc": acc, "n": int(self.x.shape[0]),
                "logits": np.asarray(logits), "y": np.asarray(self.y)}
