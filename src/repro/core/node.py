"""TL node: owns a private data shard, performs distributed-phase FP.

Per paper §3.3.1 a node, given the current model:
  1. computes first-layer activations X^(1) for its slice of the virtual
     batch (eq. 1–2),
  2. runs the full forward locally and local BP to obtain the last-layer
     gradient δ^(L) (eq. 3) and the first-layer gradient ∂L/∂X^(1),
  3. transmits only {X^(1), ∂L/∂X^(1), δ^(L)} to the orchestrator — never
     raw data or labels.

Completion of an under-specified point (recorded in DESIGN.md): the paper's
eqs. 7–11 update layers L..2 from recomputed activations but give no
∂L/∂W^(1) — which cannot be formed without the raw inputs x.  The only
privacy-preserving completion is for the node to also send its *first-layer
weight gradients* (a single layer's worth of parameters), computed during
the same local BP.  With that, TL's global update is exactly the CL update.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def ce_sum(logits, y):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1).sum()


@dataclass
class FPResult:
    """What a node ships to the orchestrator after its FP visit."""
    x1: Any                 # first-layer activations, (k, ...)
    delta_L: Any            # last-layer gradients dL/dlogits, (k, C)
    dx1: Any                # first-layer gradients dL/dX^(1), (k, ...)
    gw1: Any                # first-layer weight grads (param pytree, zeros elsewhere)
    loss_sum: float
    n_correct: int


class TLNode:
    """Holds a private shard (x, y); executes FP visits."""

    def __init__(self, node_id: int, model, x, y):
        self.node_id = node_id
        self.model = model
        self.x = jnp.asarray(x)
        self.y = jnp.asarray(y)
        self.params = None          # set by orchestrator's model distribution

    # ---- protocol surface --------------------------------------------------
    def index_range(self):
        from repro.core.virtual_batch import IndexRange
        return IndexRange(self.node_id, int(self.x.shape[0]))

    def receive_model(self, params):
        self.params = params

    def forward_visit(self, local_indices: np.ndarray, batch_total: int) -> FPResult:
        """One node visit of the traversal plan.  ``batch_total`` is the full
        virtual-batch size N so the node scales its loss to (1/N)·Σ local CE,
        making orchestrator-side aggregation a plain sum (exact CL grads for
        unequal node shares — paper eq. 6 assumes equal shares)."""
        assert self.params is not None, "model not distributed to node"
        xb = self.x[local_indices]
        yb = self.y[local_indices]
        m, params = self.model, self.params

        x1 = m.first_layer(params, xb)                                 # eq. 1–2

        # local BP: δ^(L), dL/dX^(1), and first-layer weight grads
        logits, pull_tail = jax.vjp(lambda h: m.tail_layers(params, h), x1)
        loss = ce_sum(logits, yb) / batch_total
        delta_L = jax.grad(lambda lg: ce_sum(lg, yb) / batch_total)(logits)  # eq. 3
        (dx1,) = pull_tail(delta_L)
        _, pull_first = jax.vjp(lambda p: m.first_layer(p, xb), params)
        (gw1,) = pull_first(dx1)

        acc = int((jnp.argmax(logits, -1) == yb).sum())
        return FPResult(x1=x1, delta_L=delta_L, dx1=dx1, gw1=gw1,
                        loss_sum=float(loss), n_correct=acc)

    # ---- local evaluation (inference stays on-node) -------------------------
    def evaluate(self, params):
        logits = self.model.forward(params, self.x)
        loss = float(ce_sum(logits, self.y) / self.x.shape[0])
        acc = float((jnp.argmax(logits, -1) == self.y).mean())
        return {"loss": loss, "acc": acc, "n": int(self.x.shape[0]),
                "logits": np.asarray(logits), "y": np.asarray(self.y)}
