"""Baseline distributed-learning methods the paper compares TL against:

  CL   — centralized learning (upper bound; TL must match it exactly),
  FL   — FedAvg [McMahan et al.]: local epochs + weighted model averaging,
  SL   — vanilla split learning: client holds the first layers, server the
         rest; clients processed sequentially with client-weight handoff,
  SL+  — split learning without label sharing: first AND last layers stay
         on the client, the middle runs on the server,
  SFL  — SplitFed: SL's split but clients run in parallel and their parts
         are FedAvg'd each round.

All operate on ``SmallModel``'s split API so quality comparisons
(benchmarks/table1) are apples-to-apples, and all count communication bytes
through the same ``Transport``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.node import ce_sum
from repro.core.transport import Transport


def _batches(n, bs, rng):
    idx = rng.permutation(n)
    return [idx[i:i + bs] for i in range(0, n - bs + 1, bs)]


def _tree_weighted_mean(trees, weights):
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *leaves: sum(wi * l for wi, l in zip(w, leaves)), *trees)


@dataclass
class ShardData:
    x: jnp.ndarray
    y: jnp.ndarray


# ---------------------------------------------------------------------- CL

def train_cl(model, shards: Sequence[ShardData], optimizer, *, key,
             epochs: int, batch_size: int, seed: int = 0):
    """Centralized: pool all shards (the privacy-violating upper bound)."""
    x = jnp.concatenate([s.x for s in shards])
    y = jnp.concatenate([s.y for s in shards])
    params = model.init(key)
    state = optimizer.init(params)
    rng = np.random.default_rng(seed)
    grad_fn = jax.jit(jax.grad(
        lambda p, xb, yb: ce_sum(model.forward(p, xb), yb) / xb.shape[0]))
    for _ in range(epochs):
        for idx in _batches(len(x), batch_size, rng):
            g = grad_fn(params, x[idx], y[idx])
            params, state = optimizer.update(params, g, state)
    return params


# ------------------------------------------------------------------ FedAvg

def train_fl(model, shards: Sequence[ShardData], optimizer, *, key,
             rounds: int, local_epochs: int, batch_size: int,
             transport: Optional[Transport] = None, seed: int = 0):
    """FedAvg: each round every client trains locally then the server
    averages parameters weighted by shard size (the paper's accuracy-losing
    aggregation)."""
    tr = transport or Transport()
    params = model.init(key)
    rng = np.random.default_rng(seed)
    grad_fn = jax.jit(jax.grad(
        lambda p, xb, yb: ce_sum(model.forward(p, xb), yb) / xb.shape[0]))
    for _ in range(rounds):
        locals_, sizes = [], []
        with tr.parallel():
            for s in shards:
                p_i = tr.send("model", params)                 # server -> client
                st_i = optimizer.init(p_i)
                for _e in range(local_epochs):
                    for idx in _batches(len(s.x), batch_size, rng):
                        g = grad_fn(p_i, s.x[idx], s.y[idx])
                        p_i, st_i = optimizer.update(p_i, g, st_i)
                locals_.append(tr.send("model_update", p_i))   # client -> server
                sizes.append(len(s.x))
        params = _tree_weighted_mean(locals_, sizes)           # aggregation
    return params


# ------------------------------------------------------- split-learning ops

def _split_grads(model, params, xb, yb):
    """Returns (grads wrt first-layer params, grads wrt tail params, loss),
    plus the smashed-data tensors that cross the wire in SL."""
    def loss_fn(p):
        return ce_sum(model.forward(p, xb), yb) / xb.shape[0]
    return jax.grad(loss_fn)(params)


def train_sl(model, shards: Sequence[ShardData], optimizer, *, key,
             rounds: int, batch_size: int,
             transport: Optional[Transport] = None, seed: int = 0,
             no_label_sharing: bool = False):
    """Vanilla SL (and SL+ with ``no_label_sharing``).

    Clients are visited sequentially; each trains on its local batches with
    the shared model (client part handed off from the previous client, the
    server part updated in place).  The *sequential* single-shard updates
    cause the catastrophic-forgetting quality drop the paper reports.

    Wire traffic per batch: smashed activations client->server, cut-layer
    gradients server->client (both directions sized like X^(1)); SL+ adds
    the last-layer activations/gradients round trip.
    """
    tr = transport or Transport()
    params = model.init(key)
    state = optimizer.init(params)
    rng = np.random.default_rng(seed)

    grad_fn = jax.jit(jax.grad(
        lambda p, xb, yb: ce_sum(model.forward(p, xb), yb) / xb.shape[0],
        ), static_argnums=())

    for _ in range(rounds):
        for s in shards:                       # sequential node visits
            for idx in _batches(len(s.x), batch_size, rng):
                xb, yb = s.x[idx], s.y[idx]
                smashed = model.first_layer(params, xb)
                tr.send("smashed", smashed)                    # client -> server
                if not no_label_sharing:
                    tr.send("labels", yb)
                g = grad_fn(params, xb, yb)
                tr.send("cut_grads", smashed)                  # server -> client (same size)
                if no_label_sharing:
                    # SL+ extra hop: last layer activations + grads stay client-side
                    logits = model.forward(params, xb)
                    tr.send("last_act", logits)
                    tr.send("last_grad", logits)
                params, state = optimizer.update(params, g, state)
    return params


def train_sfl(model, shards: Sequence[ShardData], optimizer, *, key,
              rounds: int, batch_size: int,
              transport: Optional[Transport] = None, seed: int = 0):
    """SplitFed: per round, clients run SL-style steps in parallel from the
    same starting weights; client parts (and server parts, splitfed-v1) are
    then FedAvg'd — combining SL's split with FL's aggregation loss."""
    tr = transport or Transport()
    params = model.init(key)
    rng = np.random.default_rng(seed)
    grad_fn = jax.jit(jax.grad(
        lambda p, xb, yb: ce_sum(model.forward(p, xb), yb) / xb.shape[0]))
    for _ in range(rounds):
        locals_, sizes = [], []
        with tr.parallel():
            for s in shards:
                p_i = tr.send("model_client_part", params)
                st_i = optimizer.init(p_i)
                for idx in _batches(len(s.x), batch_size, rng):
                    xb, yb = s.x[idx], s.y[idx]
                    tr.send("smashed", model.first_layer(p_i, xb))
                    g = grad_fn(p_i, xb, yb)
                    tr.send("cut_grads", model.first_layer(p_i, xb))
                    p_i, st_i = optimizer.update(p_i, g, st_i)
                locals_.append(tr.send("model_update", p_i))
                sizes.append(len(s.x))
        params = _tree_weighted_mean(locals_, sizes)
    return params


def evaluate(model, params, x, y) -> dict:
    logits = model.forward(params, jnp.asarray(x))
    pred = np.asarray(jnp.argmax(logits, -1))
    y = np.asarray(y)
    acc = float((pred == y).mean())
    out = {"acc": acc}
    # macro F1
    classes = np.unique(y)
    f1s = []
    for c in classes:
        tp = ((pred == c) & (y == c)).sum()
        fp = ((pred == c) & (y != c)).sum()
        fn = ((pred != c) & (y == c)).sum()
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1s.append(0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec))
    out["macro_f1"] = float(np.mean(f1s))
    # AUC (binary only, rank-based)
    if len(classes) == 2:
        score = np.asarray(jax.nn.softmax(logits, -1))[:, 1]
        order = np.argsort(score)
        ranks = np.empty_like(order, dtype=np.float64)
        ranks[order] = np.arange(1, len(score) + 1)
        pos = y == 1
        n_pos, n_neg = pos.sum(), (~pos).sum()
        if n_pos and n_neg:
            out["auc"] = float(
                (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
    return out
