"""Virtual batch creation — Algorithm 1 of the paper, faithfully.

Steps (paper §3.1):
  1. Index Range Retrieval   — orchestrator queries nodes for local index
                               ranges only (never raw data).
  2. Global Re-Indexing      — each sample gets a unique global id.
  3. Shuffling & Re-Ordering — the global map is shuffled and grouped into
                               virtual batches spanning nodes.
  4. Traversal Plan Generation — per batch, the sequence of node visits
                               during FP (order of first appearance of each
                               node's samples in the shuffled batch).

Non-sequential (privacy-hardened) global ids are supported per §5.3: the
orchestrator can assign a random permutation of ids so ranges reveal no
structure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class IndexRange:
    """What a node discloses: its id and how many samples it holds."""
    node_id: int
    n_samples: int


@dataclass(frozen=True)
class NodeSegment:
    """One node visit in a traversal plan: which *local* indices to process,
    and where their outputs land inside the virtual batch."""
    node_id: int
    local_indices: np.ndarray        # (k,) local sample positions on the node
    batch_positions: np.ndarray      # (k,) positions inside the virtual batch


@dataclass(frozen=True)
class VirtualBatch:
    batch_id: int
    global_ids: np.ndarray           # (batch,) shuffled global ids
    traversal: Tuple[NodeSegment, ...]   # ordered node visits

    @property
    def size(self) -> int:
        return len(self.global_ids)


@dataclass(frozen=True)
class VirtualBatchPlan:
    batches: Tuple[VirtualBatch, ...]
    global_to_node: np.ndarray       # (N,) node id per global id
    global_to_local: np.ndarray      # (N,) local index per global id
    n_nodes: int

    @property
    def n_samples(self) -> int:
        return len(self.global_to_node)


def global_reindex(ranges: Sequence[IndexRange], *, randomize_ids: bool = False,
                   seed: int = 0):
    """Step 2: build the global index map.  Returns (node_of, local_of)."""
    ranges = sorted(ranges, key=lambda r: r.node_id)
    node_of = np.concatenate([np.full(r.n_samples, r.node_id, np.int64)
                              for r in ranges])
    local_of = np.concatenate([np.arange(r.n_samples, dtype=np.int64)
                               for r in ranges])
    if randomize_ids:
        # §5.3: non-sequential unique ids break the data↔range correlation
        perm = np.random.default_rng(seed).permutation(len(node_of))
        node_of, local_of = node_of[perm], local_of[perm]
    return node_of, local_of


def make_traversal(global_ids: np.ndarray, node_of: np.ndarray,
                   local_of: np.ndarray) -> Tuple[NodeSegment, ...]:
    """Step 4: node-visit sequence for one virtual batch.

    Nodes are visited in order of first appearance in the shuffled batch;
    each visit covers all of that node's samples in the batch (so each node
    is visited exactly once per batch — the paper's 'sequence of nodes').
    """
    segs: List[NodeSegment] = []
    seen: Dict[int, int] = {}
    order: List[int] = []
    for pos, gid in enumerate(global_ids):
        nid = int(node_of[gid])
        if nid not in seen:
            seen[nid] = len(order)
            order.append(nid)
    for nid in order:
        mask = node_of[global_ids] == nid
        positions = np.nonzero(mask)[0]
        segs.append(NodeSegment(
            node_id=nid,
            local_indices=local_of[global_ids[positions]].copy(),
            batch_positions=positions.astype(np.int64),
        ))
    return tuple(segs)


def assert_exactly_once(size: int, segments: Sequence[NodeSegment]) -> None:
    """Verify a set of collected segments assembles every virtual-batch row
    exactly once: their ``batch_positions`` must partition ``0..size-1``.

    This is the reassembly-permutation invariant the fault-recovery path
    re-derives after retries and replica failover (``repro.core.faults``):
    however many attempts a segment took, its rows must land in the virtual
    batch once and only once.  Raises ``RuntimeError`` on violation rather
    than letting a corrupted perm silently scatter rows on top of each
    other."""
    pos = (np.concatenate([s.batch_positions for s in segments])
           if segments else np.empty((0,), np.int64))
    if len(pos) != size:
        raise RuntimeError(
            f"virtual batch assembled {len(pos)} rows, expected {size}: "
            "a traversal segment was lost or duplicated during recovery")
    counts = np.bincount(pos.astype(np.int64), minlength=size)
    if (counts != 1).any():
        bad = np.nonzero(counts != 1)[0][:8]
        raise RuntimeError(
            "virtual-batch rows not assembled exactly once (positions "
            f"{bad.tolist()} covered {counts[bad].tolist()} times)")


def assert_covers_traversal(vb: VirtualBatch,
                            segments: Sequence[NodeSegment]) -> None:
    """Verify collected segments cover exactly the batch's own traversal.

    The generalization of :func:`assert_exactly_once` that restricted
    (subtree) batches need: a child batch's traversal covers only its
    subtree's rows, so the collected ``batch_positions`` must equal the
    planned ones as a multiset — each planned row assembled once and only
    once, no foreign rows.  For a full batch the planned positions
    partition ``0..size-1`` by construction (:func:`make_traversal`), so
    this is exactly the old check."""
    planned = (np.concatenate([s.batch_positions for s in vb.traversal])
               if vb.traversal else np.empty((0,), np.int64))
    got = (np.concatenate([s.batch_positions for s in segments])
           if segments else np.empty((0,), np.int64))
    if len(got) != len(planned):
        raise RuntimeError(
            f"virtual batch {vb.batch_id} assembled {len(got)} rows, "
            f"planned {len(planned)}: a traversal segment was lost or "
            "duplicated during recovery")
    if not np.array_equal(np.sort(got.astype(np.int64)),
                          np.sort(planned.astype(np.int64))):
        raise RuntimeError(
            f"virtual batch {vb.batch_id} rows not assembled exactly as "
            "planned: collected batch positions differ from the "
            "traversal's (a row was dropped, duplicated, or came from "
            "outside this batch's plan)")


def create_virtual_batches(ranges: Sequence[IndexRange], batch_size: int,
                           *, seed: int = 0, randomize_ids: bool = False,
                           drop_remainder: bool = True) -> VirtualBatchPlan:
    """Algorithm 1 end-to-end."""
    node_of, local_of = global_reindex(ranges, randomize_ids=randomize_ids,
                                       seed=seed + 1)
    n = len(node_of)
    rng = np.random.default_rng(seed)
    shuffled = rng.permutation(n)                       # step 3
    n_batches = n // batch_size if drop_remainder else -(-n // batch_size)
    batches = []
    for b in range(n_batches):
        gids = shuffled[b * batch_size:(b + 1) * batch_size]
        batches.append(VirtualBatch(
            batch_id=b,
            global_ids=gids,
            traversal=make_traversal(gids, node_of, local_of),
        ))
    return VirtualBatchPlan(
        batches=tuple(batches),
        global_to_node=node_of,
        global_to_local=local_of,
        n_nodes=len({r.node_id for r in ranges}),
    )
