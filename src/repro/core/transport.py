"""In-process message transport with byte accounting and a network model.

Every orchestrator↔node exchange in the protocol simulator goes through a
``Transport``, which
  * counts payload bytes per direction and per message tag,
  * optionally compresses eligible float tensors per tag through a
    :class:`WirePolicy` — {off, int8, fp8} × {error feedback on/off}
    (paper §5.2, ``repro.kernels.act_compress``),
  * advances a virtual clock with a latency/bandwidth model so the paper's
    runtime equations (15–19) can be compared against 'measured' simulated
    time.  Parallel transfers (the paper's pipelined communication) are
    modeled with ``parallel``: transfers inside a window overlap and cost
    max() instead of sum().

Wire compression (``WirePolicy``): each tag gets a :class:`LaneSpec`
(codec ∈ {off, int8, fp8}, error-feedback flag).  A compressed send
charges the *compressed* bytes (1 B/element + one 4 B f32 scale per row,
``act_compress.compressed_bytes``) and appends a ``wire:{codec}``
WindowRecord carrying ``meta={"raw_bytes", "ratio"}`` so ``window_log``
measures the bandwidth win per send; ``raw_bytes`` keeps the per-tag
uncompressed totals for the same comparison in aggregate.  Error feedback
keeps one residual per ``(key, tag, leaf)`` lane: each send compresses
``x + residual`` and stores the new quantization error, so a repeatedly
sent signal is transmitted losslessly in the limit.  Model parameters are
never quantized — a lossy codec on the "model" tag is a construction-time
``ValueError``.  EF composes with fault lanes: a DROP lane suspends
residual commits (the payload never arrived, so the lane's state must not
advance), which makes the retried attempt byte-identical to the dropped
one and the whole run bit-equal to its fault-free counterpart.

Cross-batch pipelining (the double-buffered epoch engine) is modeled with
``overlap``: an overlap scope holds named *lanes* that run concurrently
against each other while each lane is internally sequential.  On scope exit
the clock advances by the max over lane totals — batch k's centralized-BP
lane and batch k+1's visit lane overlap, exactly the §3.2 pipelining taken
across virtual batches.  A lane opened with ``ticks=False`` keeps compute
ticks on the serial clock (strict-mode lookahead may only prefetch payload
*transfers*; node compute still waits for the updated parameters).

Overlap never changes *bytes*: accounting of ``bytes_sent`` per tag is
identical however windows and lanes are arranged — only ``clock_s`` moves.
Every closed window/scope is appended to ``window_log`` for per-window
byte/clock inspection.

Fault lanes (``repro.core.faults``): a transport built with a
``FaultInjector`` exposes ``fault_lane(key)`` — every transfer and compute
tick inside the lane is subject to the injector's seeded per-attempt
verdict for ``key``.  A *straggling* lane multiplies its clock costs by the
straggle factor (bytes unchanged); a *dropped* lane charges its transfers
normally (the payload burned wire time before it was lost) and raises
``VisitDropped`` at lane exit so the caller retries.  Either way a
``WindowRecord(kind="fault:drop" | "fault:straggle")`` lands in
``window_log`` with the attempt's bytes and clock, so the retry cost is
inspectable: total bytes = fault-free bytes + the sum of ``fault:drop``
record bytes, exactly — never silently double-counted.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.faults import (DROP, OK, FaultEvent, FaultInjector,
                               VisitDropped, VisitOutcome)


@dataclass
class NetworkModel:
    bandwidth_bytes_per_s: float = 1e9 / 8        # 1 Gb/s WAN link
    rtt_s: float = 0.02

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt_s + nbytes / self.bandwidth_bytes_per_s


_WIRE_CODECS = ("off", "int8", "fp8")


@dataclass(frozen=True)
class LaneSpec:
    """Wire treatment for one message tag: which quantization rung (if
    any) and whether the lane runs an error-feedback accumulator."""
    codec: str = "off"                  # "off" | "int8" | "fp8"
    error_feedback: bool = False

    def __post_init__(self):
        if self.codec not in _WIRE_CODECS:
            raise ValueError(f"unknown wire codec {self.codec!r}; "
                             f"one of {_WIRE_CODECS}")
        if self.error_feedback and self.codec == "off":
            raise ValueError("error_feedback requires a lossy codec")


@dataclass(frozen=True)
class WirePolicy:
    """Per-tag wire compression policy.  Tags without an entry ship raw.

    The "model" tag may never carry a lossy codec: TL's losslessness
    argument requires every node to train against *exactly* the
    orchestrator's parameters, so quantizing the redistribution would
    silently break the centralized-equivalence grid."""
    lanes: Dict[str, LaneSpec] = field(default_factory=dict)

    def __post_init__(self):
        for tag, spec in self.lanes.items():
            if tag == "model" and spec.codec != "off":
                raise ValueError(
                    "model parameters must never quantize (lossy codec "
                    f"{spec.codec!r} on tag 'model')")

    def lane(self, tag: str) -> LaneSpec:
        return self.lanes.get(tag, _LANE_OFF)

    @classmethod
    def visits(cls, codec: str, *, error_feedback: bool = False
               ) -> Optional["WirePolicy"]:
        """Policy compressing the visit payload tag ("activations_grads")
        at ``codec``; ``codec="off"`` returns ``None`` (no policy)."""
        if codec == "off":
            return None
        return cls({"activations_grads":
                    LaneSpec(codec, error_feedback=error_feedback)})


_LANE_OFF = LaneSpec()


def _leaf_bytes(leaf) -> int:
    """Wire size of one pytree leaf: array leaves by their buffer size,
    python scalars as 8 bytes, anything else free (metadata)."""
    if hasattr(leaf, "nbytes"):
        return int(leaf.nbytes)
    if isinstance(leaf, (int, float, bool)):
        return 8
    return 0


def payload_bytes(tree) -> int:
    return sum(_leaf_bytes(leaf) for leaf in jax.tree.leaves(tree))


def _fold_entries(entries) -> Tuple[float, Dict[str, int]]:
    """Fold (time_s, tag, nbytes) entries into (sequential total, per-tag
    bytes) — the aggregation every sequential scope (chain, fault lane)
    applies on exit."""
    t = sum(e[0] for e in entries)
    by_tag: Dict[str, int] = {}
    for _, tag, nb in entries:
        if nb:
            by_tag[tag] = by_tag.get(tag, 0) + nb
    return t, by_tag


@dataclass
class WindowRecord:
    """Per-window accounting entry: how long the window cost on the clock
    and which tags moved how many bytes inside it.  Nested scopes each log
    their own record (a parallel window inside an overlap lane appears in
    both), so the log is hierarchical — don't sum ``nbytes`` across records
    expecting ``total_bytes``."""
    kind: str               # "parallel" | "overlap" | "fault:*" | "wire:*"
    clock_s: float
    nbytes: int
    by_tag: Dict[str, int] = field(default_factory=dict)
    lanes: Dict[str, float] = field(default_factory=dict)   # overlap only
    # overlap only: per-lane per-tag bytes.  Sums to ``by_tag`` exactly —
    # a byte moved in one lane is attributed to that lane and no other, so
    # nested orchestrators (one lane per subtree) can reconcile each
    # subtree against the root ledger without re-walking nested records
    # (which double-counts: a parallel window inside a lane logs its own
    # record too).
    lane_bytes: Dict[str, Dict[str, int]] = field(default_factory=dict)
    meta: Dict[str, float] = field(default_factory=dict)    # fault/wire only


class _OverlapScope:
    """Named concurrent lanes inside one ``Transport.overlap()`` scope."""

    def __init__(self, transport: "Transport"):
        self._tr = transport
        self.totals: Dict[str, float] = {}       # lane name -> sequential time
        self.by_tag: Dict[str, int] = {}
        self.lane_bytes: Dict[str, Dict[str, int]] = {}  # lane -> tag -> B
        self.nbytes = 0

    @contextlib.contextmanager
    def lane(self, name: str, *, ticks: bool = True):
        """One concurrent lane.  Transfers (and windows) inside it sum into
        the lane.  ``ticks=False`` routes ``tick()`` compute time to the
        serial clock instead — strict-mode prefetch overlaps transfers only.
        Re-entering a name accumulates into the same lane."""
        tr = self._tr
        # a lane inside an open parallel window would have its transfers
        # claimed by the window (deposit precedence) and total 0 — forbid
        # the composition instead of silently under-counting
        assert tr._window is None, \
            "overlap lane cannot open inside a parallel() window; " \
            "open parallel() windows inside the lane instead"
        outer, outer_ticks = tr._lane, tr._lane_ticks
        tr._lane, tr._lane_ticks = [], ticks
        try:
            yield
        finally:
            entries, tr._lane, tr._lane_ticks = tr._lane, outer, outer_ticks
            self.totals[name] = (self.totals.get(name, 0.0)
                                 + sum(e[0] for e in entries))
            mine = self.lane_bytes.setdefault(name, {})
            for _, tag, nb in entries:
                if nb:
                    self.by_tag[tag] = self.by_tag.get(tag, 0) + nb
                    mine[tag] = mine.get(tag, 0) + nb
                    self.nbytes += nb


@dataclass
class Transport:
    network: NetworkModel = field(default_factory=NetworkModel)
    wire: Optional[WirePolicy] = None
    bytes_sent: Dict[str, int] = field(default_factory=dict)
    # per-tag *uncompressed* payload totals — always charged, wire on or
    # off, so raw_bytes[tag] / bytes_sent[tag] is the measured bytes ratio
    raw_bytes: Dict[str, int] = field(default_factory=dict)
    n_messages: int = 0
    clock_s: float = 0.0
    window_log: List[WindowRecord] = field(default_factory=list)
    # fault injection (repro.core.faults): seeded per-visit verdicts applied
    # inside fault_lane() scopes; None = a perfectly reliable network
    faults: Optional[FaultInjector] = None
    fault_log: List[FaultEvent] = field(default_factory=list)
    # active sinks: a parallel window costs max() of its entries, an overlap
    # lane costs sum(); entries are (time_s, tag, nbytes)
    _window: Optional[List[Tuple[float, str, int]]] = None
    _lane: Optional[List[Tuple[float, str, int]]] = None
    _lane_ticks: bool = True
    # active fault lane: clock multiplier + per-lane entry capture (for the
    # fault WindowRecord — copies; deposits still flow to window/lane/clock)
    _fault_factor: float = 1.0
    _fault_entries: Optional[List[Tuple[float, str, int]]] = None
    # error-feedback residual store, keyed (key, tag, leaf_index); commits
    # are suspended inside DROP fault lanes (payload never delivered)
    _ef_residuals: Dict[Tuple, object] = field(default_factory=dict,
                                               repr=False)
    _ef_suspended: bool = False

    # ---- bookkeeping -----------------------------------------------------
    def _deposit(self, t: float, tag: str, nbytes: int):
        if self._window is not None:
            self._window.append((t, tag, nbytes))
        elif self._lane is not None:
            self._lane.append((t, tag, nbytes))
        else:
            self.clock_s += t

    def _account(self, tag: str, nbytes: int):
        self.bytes_sent[tag] = self.bytes_sent.get(tag, 0) + nbytes
        self.n_messages += 1
        t = self.network.transfer_time(nbytes) * self._fault_factor
        if self._fault_entries is not None:
            self._fault_entries.append((t, tag, nbytes))
        self._deposit(t, tag, nbytes)

    @contextlib.contextmanager
    def parallel(self):
        """Transfers issued inside this context overlap (cost = max)."""
        outer = self._window
        self._window = []
        try:
            yield
        finally:
            entries, self._window = self._window, outer
            if entries:
                t = max(e[0] for e in entries)
                by_tag: Dict[str, int] = {}
                for _, tag, nb in entries:
                    if nb:
                        by_tag[tag] = by_tag.get(tag, 0) + nb
                total = sum(by_tag.values())
                self.window_log.append(
                    WindowRecord("parallel", t, total, by_tag))
                # cost the window as one unit, but keep per-tag byte
                # attribution visible to the enclosing lane/window (the
                # zero-time entries can't change a max or a sum of times)
                self._deposit(t, "<window>", 0)
                for tag, nb in by_tag.items():
                    self._deposit(0.0, tag, nb)

    @contextlib.contextmanager
    def chain(self):
        """Entries inside are sequential relative to *each other* (cost =
        sum) even inside a ``parallel()`` window — a retry can never
        overlap the failed attempt it replaces, so one segment's attempts
        must not disappear into the window's ``max()``.  On exit the chain
        deposits one summed entry (plus zero-time per-tag byte entries, so
        tag attribution survives like a nested window's).  Outside a
        window this is a no-op: the serial clock and overlap lanes already
        sum."""
        if self._window is None:
            yield
            return
        outer = self._window
        self._window = []
        try:
            yield
        finally:
            entries, self._window = self._window, outer
            if entries:
                t, by_tag = _fold_entries(entries)
                self._deposit(t, "<chain>", 0)
                for tag, nb in by_tag.items():
                    self._deposit(0.0, tag, nb)

    @contextlib.contextmanager
    def overlap(self):
        """Cross-batch overlap scope: lanes opened on the yielded scope run
        concurrently; on exit the clock advances by max over lane totals.
        Open overlap scopes outside parallel() windows (windows nest inside
        lanes, not the other way around)."""
        assert self._window is None, \
            "overlap() cannot open inside a parallel() window"
        scope = _OverlapScope(self)
        try:
            yield scope
        finally:
            t = max(scope.totals.values(), default=0.0)
            self.window_log.append(
                WindowRecord("overlap", t, scope.nbytes, dict(scope.by_tag),
                             lanes=dict(scope.totals),
                             lane_bytes={k: dict(v) for k, v
                                         in scope.lane_bytes.items()}))
            self._deposit(t, "<overlap>", 0)
            for tag, nb in scope.by_tag.items():
                self._deposit(0.0, tag, nb)

    def tick(self, seconds: float):
        """Advance the clock for compute time.  Inside an overlap lane (with
        lane ticks enabled) the compute joins that lane; parallel transfer
        windows never absorb compute.  Inside a straggling fault lane the
        compute is slowed by the same factor as the transfers (a straggler
        node is slow, not just its link)."""
        seconds = seconds * self._fault_factor
        if self._fault_entries is not None:
            self._fault_entries.append((seconds, "<compute>", 0))
        if self._lane is not None and self._lane_ticks:
            self._lane.append((seconds, "<compute>", 0))
        else:
            self.clock_s += seconds

    # ---- fault lanes (repro.core.faults) ---------------------------------
    @contextlib.contextmanager
    def fault_lane(self, key: Tuple[int, ...]):
        """One visit attempt under the injector's verdict for ``key``.

        Yields the :class:`~repro.core.faults.VisitOutcome`.  A straggling
        lane multiplies every transfer/tick inside by the straggle factor;
        a dropped lane charges its costs normally and raises
        :class:`~repro.core.faults.VisitDropped` on (clean) exit — bytes
        and clock were burned, the payload was not delivered.  Non-``ok``
        lanes append a ``fault:*`` :class:`WindowRecord` (the attempt's
        bytes/clock, ``meta={"factor": ...}``) and a
        :class:`~repro.core.faults.FaultEvent` to ``fault_log``, making the
        retry cost auditable: total bytes equal fault-free bytes plus the
        sum of ``fault:drop`` record bytes, exactly."""
        key = tuple(key)
        outcome = (self.faults.decide(key) if self.faults is not None
                   else VisitOutcome(OK, key=key))
        if outcome.kind == OK:
            yield outcome
            return
        prev_factor = self._fault_factor
        prev_entries = self._fault_entries
        prev_suspended = self._ef_suspended
        self._fault_factor = prev_factor * outcome.factor
        entries: List[Tuple[float, str, int]] = []
        self._fault_entries = entries
        if outcome.kind == DROP:
            # the payload will be lost: the error-feedback lane must not
            # advance, so the retry recompresses against the *same*
            # residual and ships a byte-identical payload
            self._ef_suspended = True
        try:
            yield outcome
        finally:
            self._fault_factor = prev_factor
            self._fault_entries = prev_entries
            self._ef_suspended = prev_suspended
            t, by_tag = _fold_entries(entries)
            nbytes = sum(by_tag.values())
            self.window_log.append(WindowRecord(
                f"fault:{outcome.kind}", t, nbytes, by_tag,
                meta={"factor": outcome.factor}))
            self.fault_log.append(FaultEvent(
                key, outcome.kind, outcome.factor, clock_s=t, nbytes=nbytes))
        if outcome.kind == DROP:
            raise VisitDropped(key)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_sent.values())

    # ---- sending ---------------------------------------------------------
    def send(self, tag: str, payload, *, compressible: bool = False,
             key=None):
        """Returns the payload as the receiver sees it (possibly after a
        quantization round-trip when the tag's wire lane is on).

        ``compressible`` marks the payload as quantization-*eligible*; the
        active :class:`WirePolicy` decides whether/how the tag actually
        compresses.  ``key`` identifies the sender's error-feedback lane
        (typically the node id): residuals are kept per
        ``(key, tag, leaf)``, and a residual whose shape no longer matches
        its leaf (segment sizes vary per batch) resets to zero."""
        raw = payload_bytes(payload)
        self.raw_bytes[tag] = self.raw_bytes.get(tag, 0) + raw
        spec = (self.wire.lane(tag)
                if compressible and self.wire is not None else _LANE_OFF)
        if spec.codec == "off":
            self._account(tag, raw)
            return payload
        from repro.kernels.act_compress import (compress, compressed_bytes,
                                                decompress, ef_compress)
        out = []
        nbytes = 0
        for i, leaf in enumerate(jax.tree.leaves(payload)):
            # quantize float *tensors* only; scalars and non-float leaves
            # (loss sums, counts) are charged by their true wire size
            if hasattr(leaf, "dtype") and jnp.issubdtype(
                    leaf.dtype, jnp.floating) and leaf.ndim >= 1:
                if spec.error_feedback:
                    ef_key = (key, tag, i)
                    residual = self._ef_residuals.get(ef_key)
                    if residual is not None and residual.shape != leaf.shape:
                        residual = None
                    c, delivered, new_residual = ef_compress(
                        leaf, residual, codec=spec.codec)
                    if not self._ef_suspended:
                        self._ef_residuals[ef_key] = new_residual
                    out.append(delivered)
                else:
                    c = compress(leaf, codec=spec.codec)
                    out.append(decompress(c, leaf.shape, out_dtype=leaf.dtype))
                nbytes += compressed_bytes(c)
            else:
                nbytes += _leaf_bytes(leaf)
                out.append(leaf)
        self.window_log.append(WindowRecord(
            f"wire:{spec.codec}", 0.0, nbytes, {tag: nbytes},
            meta={"raw_bytes": raw, "ratio": raw / max(nbytes, 1)}))
        self._account(tag, nbytes)
        return jax.tree.unflatten(jax.tree.structure(payload), out)
