"""In-process message transport with byte accounting and a network model.

Every orchestrator↔node exchange in the protocol simulator goes through a
``Transport``, which
  * counts payload bytes per direction and per message tag,
  * optionally compresses eligible float tensors to int8 (paper §5.2,
    ``repro.kernels.act_compress``),
  * advances a virtual clock with a latency/bandwidth model so the paper's
    runtime equations (15–19) can be compared against 'measured' simulated
    time.  Parallel transfers (the paper's pipelined communication) are
    modeled with ``parallel``: transfers inside a window overlap and cost
    max() instead of sum().

Cross-batch pipelining (the double-buffered epoch engine) is modeled with
``overlap``: an overlap scope holds named *lanes* that run concurrently
against each other while each lane is internally sequential.  On scope exit
the clock advances by the max over lane totals — batch k's centralized-BP
lane and batch k+1's visit lane overlap, exactly the §3.2 pipelining taken
across virtual batches.  A lane opened with ``ticks=False`` keeps compute
ticks on the serial clock (strict-mode lookahead may only prefetch payload
*transfers*; node compute still waits for the updated parameters).

Overlap never changes *bytes*: accounting of ``bytes_sent`` per tag is
identical however windows and lanes are arranged — only ``clock_s`` moves.
Every closed window/scope is appended to ``window_log`` for per-window
byte/clock inspection.

Fault lanes (``repro.core.faults``): a transport built with a
``FaultInjector`` exposes ``fault_lane(key)`` — every transfer and compute
tick inside the lane is subject to the injector's seeded per-attempt
verdict for ``key``.  A *straggling* lane multiplies its clock costs by the
straggle factor (bytes unchanged); a *dropped* lane charges its transfers
normally (the payload burned wire time before it was lost) and raises
``VisitDropped`` at lane exit so the caller retries.  Either way a
``WindowRecord(kind="fault:drop" | "fault:straggle")`` lands in
``window_log`` with the attempt's bytes and clock, so the retry cost is
inspectable: total bytes = fault-free bytes + the sum of ``fault:drop``
record bytes, exactly — never silently double-counted.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.faults import (DROP, OK, FaultEvent, FaultInjector,
                               VisitDropped, VisitOutcome)


@dataclass
class NetworkModel:
    bandwidth_bytes_per_s: float = 1e9 / 8        # 1 Gb/s WAN link
    rtt_s: float = 0.02

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt_s + nbytes / self.bandwidth_bytes_per_s


def _leaf_bytes(leaf) -> int:
    """Wire size of one pytree leaf: array leaves by their buffer size,
    python scalars as 8 bytes, anything else free (metadata)."""
    if hasattr(leaf, "nbytes"):
        return int(leaf.nbytes)
    if isinstance(leaf, (int, float, bool)):
        return 8
    return 0


def payload_bytes(tree) -> int:
    return sum(_leaf_bytes(leaf) for leaf in jax.tree.leaves(tree))


def _fold_entries(entries) -> Tuple[float, Dict[str, int]]:
    """Fold (time_s, tag, nbytes) entries into (sequential total, per-tag
    bytes) — the aggregation every sequential scope (chain, fault lane)
    applies on exit."""
    t = sum(e[0] for e in entries)
    by_tag: Dict[str, int] = {}
    for _, tag, nb in entries:
        if nb:
            by_tag[tag] = by_tag.get(tag, 0) + nb
    return t, by_tag


@dataclass
class WindowRecord:
    """Per-window accounting entry: how long the window cost on the clock
    and which tags moved how many bytes inside it.  Nested scopes each log
    their own record (a parallel window inside an overlap lane appears in
    both), so the log is hierarchical — don't sum ``nbytes`` across records
    expecting ``total_bytes``."""
    kind: str                       # "parallel" | "overlap" | "fault:*"
    clock_s: float
    nbytes: int
    by_tag: Dict[str, int] = field(default_factory=dict)
    lanes: Dict[str, float] = field(default_factory=dict)   # overlap only
    meta: Dict[str, float] = field(default_factory=dict)    # fault lanes only


class _OverlapScope:
    """Named concurrent lanes inside one ``Transport.overlap()`` scope."""

    def __init__(self, transport: "Transport"):
        self._tr = transport
        self.totals: Dict[str, float] = {}       # lane name -> sequential time
        self.by_tag: Dict[str, int] = {}
        self.nbytes = 0

    @contextlib.contextmanager
    def lane(self, name: str, *, ticks: bool = True):
        """One concurrent lane.  Transfers (and windows) inside it sum into
        the lane.  ``ticks=False`` routes ``tick()`` compute time to the
        serial clock instead — strict-mode prefetch overlaps transfers only.
        Re-entering a name accumulates into the same lane."""
        tr = self._tr
        # a lane inside an open parallel window would have its transfers
        # claimed by the window (deposit precedence) and total 0 — forbid
        # the composition instead of silently under-counting
        assert tr._window is None, \
            "overlap lane cannot open inside a parallel() window; " \
            "open parallel() windows inside the lane instead"
        outer, outer_ticks = tr._lane, tr._lane_ticks
        tr._lane, tr._lane_ticks = [], ticks
        try:
            yield
        finally:
            entries, tr._lane, tr._lane_ticks = tr._lane, outer, outer_ticks
            self.totals[name] = (self.totals.get(name, 0.0)
                                 + sum(e[0] for e in entries))
            for _, tag, nb in entries:
                if nb:
                    self.by_tag[tag] = self.by_tag.get(tag, 0) + nb
                    self.nbytes += nb


@dataclass
class Transport:
    network: NetworkModel = field(default_factory=NetworkModel)
    compress_activations: bool = False
    bytes_sent: Dict[str, int] = field(default_factory=dict)
    n_messages: int = 0
    clock_s: float = 0.0
    window_log: List[WindowRecord] = field(default_factory=list)
    # fault injection (repro.core.faults): seeded per-visit verdicts applied
    # inside fault_lane() scopes; None = a perfectly reliable network
    faults: Optional[FaultInjector] = None
    fault_log: List[FaultEvent] = field(default_factory=list)
    # active sinks: a parallel window costs max() of its entries, an overlap
    # lane costs sum(); entries are (time_s, tag, nbytes)
    _window: Optional[List[Tuple[float, str, int]]] = None
    _lane: Optional[List[Tuple[float, str, int]]] = None
    _lane_ticks: bool = True
    # active fault lane: clock multiplier + per-lane entry capture (for the
    # fault WindowRecord — copies; deposits still flow to window/lane/clock)
    _fault_factor: float = 1.0
    _fault_entries: Optional[List[Tuple[float, str, int]]] = None

    # ---- bookkeeping -----------------------------------------------------
    def _deposit(self, t: float, tag: str, nbytes: int):
        if self._window is not None:
            self._window.append((t, tag, nbytes))
        elif self._lane is not None:
            self._lane.append((t, tag, nbytes))
        else:
            self.clock_s += t

    def _account(self, tag: str, nbytes: int):
        self.bytes_sent[tag] = self.bytes_sent.get(tag, 0) + nbytes
        self.n_messages += 1
        t = self.network.transfer_time(nbytes) * self._fault_factor
        if self._fault_entries is not None:
            self._fault_entries.append((t, tag, nbytes))
        self._deposit(t, tag, nbytes)

    @contextlib.contextmanager
    def parallel(self):
        """Transfers issued inside this context overlap (cost = max)."""
        outer = self._window
        self._window = []
        try:
            yield
        finally:
            entries, self._window = self._window, outer
            if entries:
                t = max(e[0] for e in entries)
                by_tag: Dict[str, int] = {}
                for _, tag, nb in entries:
                    if nb:
                        by_tag[tag] = by_tag.get(tag, 0) + nb
                total = sum(by_tag.values())
                self.window_log.append(
                    WindowRecord("parallel", t, total, by_tag))
                # cost the window as one unit, but keep per-tag byte
                # attribution visible to the enclosing lane/window (the
                # zero-time entries can't change a max or a sum of times)
                self._deposit(t, "<window>", 0)
                for tag, nb in by_tag.items():
                    self._deposit(0.0, tag, nb)

    @contextlib.contextmanager
    def chain(self):
        """Entries inside are sequential relative to *each other* (cost =
        sum) even inside a ``parallel()`` window — a retry can never
        overlap the failed attempt it replaces, so one segment's attempts
        must not disappear into the window's ``max()``.  On exit the chain
        deposits one summed entry (plus zero-time per-tag byte entries, so
        tag attribution survives like a nested window's).  Outside a
        window this is a no-op: the serial clock and overlap lanes already
        sum."""
        if self._window is None:
            yield
            return
        outer = self._window
        self._window = []
        try:
            yield
        finally:
            entries, self._window = self._window, outer
            if entries:
                t, by_tag = _fold_entries(entries)
                self._deposit(t, "<chain>", 0)
                for tag, nb in by_tag.items():
                    self._deposit(0.0, tag, nb)

    @contextlib.contextmanager
    def overlap(self):
        """Cross-batch overlap scope: lanes opened on the yielded scope run
        concurrently; on exit the clock advances by max over lane totals.
        Open overlap scopes outside parallel() windows (windows nest inside
        lanes, not the other way around)."""
        assert self._window is None, \
            "overlap() cannot open inside a parallel() window"
        scope = _OverlapScope(self)
        try:
            yield scope
        finally:
            t = max(scope.totals.values(), default=0.0)
            self.window_log.append(
                WindowRecord("overlap", t, scope.nbytes, dict(scope.by_tag),
                             lanes=dict(scope.totals)))
            self._deposit(t, "<overlap>", 0)
            for tag, nb in scope.by_tag.items():
                self._deposit(0.0, tag, nb)

    def tick(self, seconds: float):
        """Advance the clock for compute time.  Inside an overlap lane (with
        lane ticks enabled) the compute joins that lane; parallel transfer
        windows never absorb compute.  Inside a straggling fault lane the
        compute is slowed by the same factor as the transfers (a straggler
        node is slow, not just its link)."""
        seconds = seconds * self._fault_factor
        if self._fault_entries is not None:
            self._fault_entries.append((seconds, "<compute>", 0))
        if self._lane is not None and self._lane_ticks:
            self._lane.append((seconds, "<compute>", 0))
        else:
            self.clock_s += seconds

    # ---- fault lanes (repro.core.faults) ---------------------------------
    @contextlib.contextmanager
    def fault_lane(self, key: Tuple[int, ...]):
        """One visit attempt under the injector's verdict for ``key``.

        Yields the :class:`~repro.core.faults.VisitOutcome`.  A straggling
        lane multiplies every transfer/tick inside by the straggle factor;
        a dropped lane charges its costs normally and raises
        :class:`~repro.core.faults.VisitDropped` on (clean) exit — bytes
        and clock were burned, the payload was not delivered.  Non-``ok``
        lanes append a ``fault:*`` :class:`WindowRecord` (the attempt's
        bytes/clock, ``meta={"factor": ...}``) and a
        :class:`~repro.core.faults.FaultEvent` to ``fault_log``, making the
        retry cost auditable: total bytes equal fault-free bytes plus the
        sum of ``fault:drop`` record bytes, exactly."""
        key = tuple(key)
        outcome = (self.faults.decide(key) if self.faults is not None
                   else VisitOutcome(OK, key=key))
        if outcome.kind == OK:
            yield outcome
            return
        prev_factor = self._fault_factor
        prev_entries = self._fault_entries
        self._fault_factor = prev_factor * outcome.factor
        entries: List[Tuple[float, str, int]] = []
        self._fault_entries = entries
        try:
            yield outcome
        finally:
            self._fault_factor = prev_factor
            self._fault_entries = prev_entries
            t, by_tag = _fold_entries(entries)
            nbytes = sum(by_tag.values())
            self.window_log.append(WindowRecord(
                f"fault:{outcome.kind}", t, nbytes, by_tag,
                meta={"factor": outcome.factor}))
            self.fault_log.append(FaultEvent(
                key, outcome.kind, outcome.factor, clock_s=t, nbytes=nbytes))
        if outcome.kind == DROP:
            raise VisitDropped(key)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_sent.values())

    # ---- sending ---------------------------------------------------------
    def send(self, tag: str, payload, *, compressible: bool = False):
        """Returns the payload as the receiver sees it (possibly after an
        int8 round-trip when compression is on)."""
        if compressible and self.compress_activations:
            from repro.kernels.act_compress import (compress, compressed_bytes,
                                                    decompress)
            out = []
            nbytes = 0
            for leaf in jax.tree.leaves(payload):
                # int8-compress float *tensors* only; scalars and non-float
                # leaves are charged by their true wire size (not a silent
                # 8-byte default for anything lacking .nbytes)
                if hasattr(leaf, "dtype") and jnp.issubdtype(
                        leaf.dtype, jnp.floating) and leaf.ndim >= 1:
                    c = compress(leaf)
                    nbytes += compressed_bytes(c)
                    out.append(decompress(c, leaf.shape, out_dtype=leaf.dtype))
                else:
                    nbytes += _leaf_bytes(leaf)
                    out.append(leaf)
            self._account(tag, nbytes)
            return jax.tree.unflatten(jax.tree.structure(payload), out)
        self._account(tag, payload_bytes(payload))
        return payload
