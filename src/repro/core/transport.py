"""In-process message transport with byte accounting and a network model.

Every orchestrator↔node exchange in the protocol simulator goes through a
``Transport``, which
  * counts payload bytes per direction and per message tag,
  * optionally compresses eligible float tensors to int8 (paper §5.2,
    ``repro.kernels.act_compress``),
  * advances a virtual clock with a latency/bandwidth model so the paper's
    runtime equations (15–19) can be compared against 'measured' simulated
    time.  Parallel transfers (the paper's pipelined communication) are
    modeled with ``parallel_window``: transfers inside a window overlap and
    cost max() instead of sum().
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class NetworkModel:
    bandwidth_bytes_per_s: float = 1e9 / 8        # 1 Gb/s WAN link
    rtt_s: float = 0.02

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt_s + nbytes / self.bandwidth_bytes_per_s


def _leaf_bytes(leaf) -> int:
    """Wire size of one pytree leaf: array leaves by their buffer size,
    python scalars as 8 bytes, anything else free (metadata)."""
    if hasattr(leaf, "nbytes"):
        return int(leaf.nbytes)
    if isinstance(leaf, (int, float, bool)):
        return 8
    return 0


def payload_bytes(tree) -> int:
    return sum(_leaf_bytes(leaf) for leaf in jax.tree.leaves(tree))


@dataclass
class Transport:
    network: NetworkModel = field(default_factory=NetworkModel)
    compress_activations: bool = False
    bytes_sent: Dict[str, int] = field(default_factory=dict)
    n_messages: int = 0
    clock_s: float = 0.0
    _window: Optional[List[float]] = None

    # ---- bookkeeping -----------------------------------------------------
    def _account(self, tag: str, nbytes: int):
        self.bytes_sent[tag] = self.bytes_sent.get(tag, 0) + nbytes
        self.n_messages += 1
        t = self.network.transfer_time(nbytes)
        if self._window is not None:
            self._window.append(t)
        else:
            self.clock_s += t

    @contextlib.contextmanager
    def parallel(self):
        """Transfers issued inside this context overlap (cost = max)."""
        outer = self._window
        self._window = []
        try:
            yield
        finally:
            if self._window:
                t = max(self._window)
                if outer is not None:
                    outer.append(t)
                else:
                    self.clock_s += t
            self._window = outer

    def tick(self, seconds: float):
        """Advance the clock for compute time."""
        self.clock_s += seconds

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_sent.values())

    # ---- sending ---------------------------------------------------------
    def send(self, tag: str, payload, *, compressible: bool = False):
        """Returns the payload as the receiver sees it (possibly after an
        int8 round-trip when compression is on)."""
        if compressible and self.compress_activations:
            from repro.kernels.act_compress import (compress, compressed_bytes,
                                                    decompress)
            out = []
            nbytes = 0
            for leaf in jax.tree.leaves(payload):
                # int8-compress float *tensors* only; scalars and non-float
                # leaves are charged by their true wire size (not a silent
                # 8-byte default for anything lacking .nbytes)
                if hasattr(leaf, "dtype") and jnp.issubdtype(
                        leaf.dtype, jnp.floating) and leaf.ndim >= 1:
                    c = compress(leaf)
                    nbytes += compressed_bytes(c)
                    out.append(decompress(c, leaf.shape, out_dtype=leaf.dtype))
                else:
                    nbytes += _leaf_bytes(leaf)
                    out.append(leaf)
            self._account(tag, nbytes)
            return jax.tree.unflatten(jax.tree.structure(payload), out)
        self._account(tag, payload_bytes(payload))
        return payload
