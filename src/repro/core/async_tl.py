"""Paper §3.4 optional machinery: asynchronous updates for WAN deployments.

Three mechanisms, each a faithful implementation of a paragraph in §3.4:

* **Gradient buffer** — the orchestrator stores late node contributions and
  applies an update only once ``min_contributions`` of the virtual batch's
  node visits have arrived; stale contributions (older than
  ``max_staleness`` versions) are dropped instead of polluting the model.
* **Adaptive traversal** — nodes are prioritized by their recent response
  latency (EMA); the traversal plan for the next batch visits fast nodes
  first so slow nodes overlap with the orchestrator's BP.
* **Reduced sync frequency** — nodes may run ``local_fp_passes`` forward
  visits before the orchestrator synchronizes, trading staleness for
  bandwidth (the paper's "nodes may perform multiple FP passes before
  synchronizing").

These knobs intentionally BREAK exact losslessness (that is the paper's
stated trade-off); tests assert both that they work and that the strict
mode remains the default.

On a ``fused`` orchestrator (the default) each buffered contribution's
centralized BP runs through the orchestrator's cached jitted
per-contribution step (``TLOrchestrator._get_contrib_step``) instead of an
eager per-call ``jax.vjp``; ``fused=False`` keeps the eager oracle.

The hierarchical orchestrator (``repro.core.hierarchy``) reuses the
:class:`GradientBuffer` drain as its root merge: unlike the async WAN
case, every per-subtree contribution there is a *complete* pre-scaled
partial sum of the same virtual batch at the same model version, so the
buffered sum is the flat full-batch gradient up to f32 reassociation —
the buffer's machinery, without its staleness trade-off.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import RecoveryPolicy, VisitDropped


@dataclass
class BufferedContribution:
    node_id: int
    model_version: int
    grads: object            # param-pytree gradient contribution
    loss_sum: float
    n_samples: int


@dataclass
class GradientBuffer:
    """Orchestrator-side buffer for late/async node contributions."""

    min_contributions: int
    max_staleness: int = 1
    _items: List[BufferedContribution] = field(default_factory=list)
    n_dropped_stale: int = 0

    def add(self, contrib: BufferedContribution, current_version: int):
        if current_version - contrib.model_version > self.max_staleness:
            self.n_dropped_stale += 1
            return
        self._items.append(contrib)

    def ready(self) -> bool:
        return len(self._items) >= self.min_contributions

    def drain(self):
        """Weighted-mean of buffered gradient contributions."""
        items, self._items = self._items, []
        total = sum(c.n_samples for c in items)
        if total == 0:
            return None, 0.0, 0
        grads = jax.tree.map(
            lambda *leaves: sum(l for l in leaves), *[c.grads for c in items])
        # contributions are pre-scaled by 1/batch on the node; the weighted
        # combination is therefore a plain sum (DESIGN.md §8.3)
        loss = sum(c.loss_sum for c in items)
        return grads, loss, total


@dataclass
class LatencyTracker:
    """EMA of per-node response latency for adaptive traversal (§3.4)."""

    alpha: float = 0.3
    latency: Dict[int, float] = field(default_factory=dict)

    def observe(self, node_id: int, seconds: float):
        prev = self.latency.get(node_id, seconds)
        self.latency[node_id] = (1 - self.alpha) * prev + self.alpha * seconds

    def priority_order(self, node_ids) -> List[int]:
        return sorted(node_ids, key=lambda n: self.latency.get(n, 0.0))

    def reorder_traversal(self, traversal):
        """Reorder a virtual batch's node segments fastest-first."""
        order = {n: i for i, n in enumerate(
            self.priority_order([s.node_id for s in traversal]))}
        return tuple(sorted(traversal, key=lambda s: order[s.node_id]))


def async_train_epoch(orch, *, min_contributions: Optional[int] = None,
                      max_staleness: int = 1,
                      node_latency_fn=lambda node_id: 0.0):
    """Run one epoch of buffered/asynchronous TL on a ``TLOrchestrator``.

    Each virtual batch's node visits are issued against the model version
    the node last received; the orchestrator applies an update as soon as
    ``min_contributions`` visits are buffered (defaults to all), dropping
    contributions staler than ``max_staleness``.  Returns per-update stats.
    """
    from repro.core.orchestrator import StepStats

    plan = orch.build_plan(orch._epoch)
    node_by_id = {n.node_id: n for n in orch.nodes}
    tracker = LatencyTracker()
    version = 0
    node_version: Dict[int, int] = {}
    stats: List[StepStats] = []

    for vb in plan.batches:
        buf = GradientBuffer(
            min_contributions=min_contributions or len(vb.traversal),
            max_staleness=max_staleness)
        traversal = tracker.reorder_traversal(vb.traversal)
        for seg in traversal:
            node = node_by_id[seg.node_id]
            if node_version.get(seg.node_id) != version:
                node.receive_model(
                    orch.transport.send("model", orch.params))
                node_version[seg.node_id] = version
            lat = node_latency_fn(seg.node_id)
            tracker.observe(seg.node_id, lat)
            orch.transport.tick(lat)
            # fault lanes (repro.core.faults): retry a dropped visit up to
            # the recovery budget; a persistently failing contribution is
            # *skipped* rather than fatal — the gradient buffer's
            # min_contributions semantics already tolerate missing visits
            # (async mode trades exactness for liveness by design)
            pol = getattr(orch, "recovery", None) or RecoveryPolicy()
            fp = wire = None
            for attempt in range(pol.max_attempts):
                try:
                    with orch.transport.fault_lane(
                            (orch._epoch, vb.batch_id, seg.node_id, attempt)):
                        fp = node.forward_visit(seg.local_indices, vb.size)
                        wire = orch.transport.send(
                            "activations_grads",
                            {"x1": fp.x1, "delta_L": fp.delta_L,
                             "gw1": fp.gw1},
                            compressible=True, key=seg.node_id)
                    break
                except VisitDropped:
                    wire = None
                    # back off only before an attempt that will happen —
                    # the clock must not charge a retry that is never made
                    if pol.backoff_s and attempt + 1 < pol.max_attempts:
                        orch.transport.tick(pol.backoff_s * (attempt + 1))
            if wire is None:
                continue
            # centralized BP for this contribution (recompute from X^(1)).
            # gw1 may be a pruned {leaf_index: array} dict (jitted nodes) or
            # a full param pytree (eager reference nodes); either way it
            # flows into the gradient tree as-is — the pruned leaf dicts
            # stay pruned end to end up to this point.
            if getattr(orch, "fused", False):
                # the orchestrator's cached jitted per-contribution step
                # (compile-once, shared across batches/epochs).  The step
                # reassembles the contribution's rows by their rank within
                # the segment's virtual-batch positions — the fused step's
                # reassembly restricted to one segment, under the
                # orchestrator's configured strategy (xla / pallas).
                ranks = np.argsort(np.argsort(seg.batch_positions))
                grads = orch._get_contrib_step()(
                    orch.params, wire["x1"], wire["delta_L"], wire["gw1"],
                    jnp.asarray(ranks.astype(np.int32)))
            else:
                from repro.core.node import add_first_layer_grads
                _, pull = jax.vjp(
                    lambda p, h: orch.model.tail_layers(p, h), orch.params,
                    wire["x1"])
                g_tail, _ = pull(wire["delta_L"])
                grads = add_first_layer_grads(g_tail, wire["gw1"])
            buf.add(BufferedContribution(
                node_id=seg.node_id,
                model_version=node_version[seg.node_id],
                grads=grads, loss_sum=float(fp.loss_sum),
                n_samples=len(seg.local_indices)), version)
            if buf.ready():
                g, loss, n = buf.drain()
                if g is not None:
                    orch.params, orch.opt_state = orch.opt.update(
                        orch.params, g, orch.opt_state)
                    version += 1
                    stats.append(StepStats(loss=loss, acc=float("nan"),
                                           grad_consistency=float("nan")))
        # flush any leftovers at batch end
        if buf._items:
            g, loss, n = buf.drain()
            if g is not None:
                orch.params, orch.opt_state = orch.opt.update(
                    orch.params, g, orch.opt_state)
                version += 1
                stats.append(StepStats(loss=loss, acc=float("nan"),
                                       grad_consistency=float("nan")))
    orch._epoch += 1
    return stats, tracker
