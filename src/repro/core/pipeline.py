"""Double-buffered epoch engine: cross-batch pipelining of the TL round.

The paper's §3.2 pipelining overlaps transfers with compute *within* one
virtual batch (one node's payload upload rides alongside the next node's
forward visit).  This engine takes the same idea *across* batches: while
batch k's centralized BP runs on the orchestrator, batch k+1's model
redistribution and node visits are already being produced.  The epoch loop
is split into a visit **producer** and a BP **consumer** joined by a 2-deep
payload queue (the double buffer: the batch being consumed + the batch
being prefetched).

Losslessness — this is a *reordering*, never an approximation:

* ``cache_model_per_epoch=True`` — every batch's visits run against the
  epoch-start parameters anyway (the §5.2 staleness the caller already
  opted into), so batch k+1's visits are fully independent of batch k's
  update.  Both the node compute and the transfers of batch k+1 overlap
  batch k's BP (``overlap`` lane with ``ticks=True``).
* strict mode (default) — batch k+1's visits need batch k's *updated*
  parameters, so only a one-step lookahead prefetch of the payload
  *transfers* is admissible: the updated parameters stream out layer-by-
  layer as the optimizer produces them and the visit payload uploads of
  batch k+1 ride the otherwise-idle link during batch k's BP, while node
  compute itself stays on the serial clock (``ticks=False`` lane).
  Numerically the engine issues the fused BP step asynchronously (JAX
  futures) and the visits consume the future parameters — the device
  dependency graph preserves the exact serial arithmetic.

Either way the final parameters are bit-for-bit those of the serial epoch
loop (see ``tests/test_pipelined_equivalence.py``'s cross-path grid), and
``Transport.bytes_sent`` is untouched — overlap changes the simulated
clock, never bytes.

``donate=True`` stays safe under prefetch because of dispatch ordering,
not reference counting: every consumer of parameter generation g (batch
g's visits) is dispatched before the step that donates g is dispatched —
the engine's producer runs strictly after the consumer's ``apply_update``
within each overlap scope, and the payload queue retains batch k's wires
until its BP has been issued.  A donating step can therefore never
invalidate a buffer with un-dispatched consumers.  (Holding extra Python
references would NOT provide this guarantee — donation deletes the buffer
at dispatch regardless of refcount.)
"""
from __future__ import annotations

from collections import deque
from typing import List

from repro.core.virtual_batch import VirtualBatch


class PipelinedEpochEngine:
    """Visit-producer / BP-consumer epoch driver over a ``TLOrchestrator``.

    The payload queue is the double buffer: it holds the batch currently
    being consumed *and* the prefetched next batch (never more — deeper
    prefetch would require parameters that do not exist yet in strict
    mode, and is asserted against rather than silently dropped).
    """

    QUEUE_DEPTH = 2

    def __init__(self, orch):
        self.orch = orch
        self._queue: deque = deque()
        self.max_queue_depth = 0          # observability (tested invariant)

    def _enqueue(self, item):
        assert len(self._queue) < self.QUEUE_DEPTH, \
            "payload queue overflow: prefetch deeper than the double buffer"
        self._queue.append(item)
        self.max_queue_depth = max(self.max_queue_depth, len(self._queue))

    # ------------------------------------------------------------- producer
    def _produce(self, vb: VirtualBatch, node_by_id, scope=None):
        """Collect batch ``vb``'s visit payloads.  Inside an overlap
        ``scope`` the work joins the "visits" lane; in strict mode only the
        transfers overlap (compute ticks stay serial).

        Wire compression rides along untouched: this routes through
        ``orch._collect_visits`` which issues the per-segment ``send``
        calls in the same Python order as the serial path, so an
        error-feedback wire sees an identical residual sequence per
        ``(node, tag)`` lane and the pipelined run stays bit-equal to the
        serial one, compressed or not."""
        orch = self.orch
        if scope is None:
            results, order = orch._collect_visits(vb, node_by_id, issue=True)
        else:
            with scope.lane("visits", ticks=orch.cache_model_per_epoch):
                results, order = orch._collect_visits(vb, node_by_id,
                                                      issue=True)
        return vb, results, order

    # -------------------------------------------------------------- epochs
    def run_epoch(self, *, start_batch: int = 0,
                  max_batches: int | None = None) -> List:
        """One (possibly resumed/truncated) epoch through the double
        buffer.  ``start_batch``/``max_batches`` mirror
        ``TLOrchestrator.train_epoch`` — the :class:`~repro.core.plan.
        TraversalPlan` is re-derived from the planner's pure
        ``(seed, epoch)`` function and sliced, so a killed pipelined run
        resumes on exactly the batches whose updates the checkpoint
        lacks."""
        orch = self.orch
        tr = orch.transport
        plan = orch.build_plan(orch._epoch)
        batches, completes = orch._epoch_batches(plan, start_batch,
                                                 max_batches)
        node_by_id = {n.node_id: n for n in orch.nodes}
        stats: List = []

        if orch.cache_model_per_epoch:
            with tr.parallel():
                for n in orch.nodes:
                    # executor-aware: an evicted primary's replica carries
                    # its segments and needs the epoch parameters
                    orch._executor(n.node_id, node_by_id).receive_model(
                        tr.send("model", orch.params))

        if batches:
            # pipeline fill: batch 0 has nothing to overlap with
            self._enqueue(self._produce(batches[0], node_by_id))

        for k in range(len(batches)):
            # current batch stays queued (payloads referenced) until its BP
            # has been issued and the next batch produced
            vb, results, order = self._queue[0]
            nxt = batches[k + 1] if k + 1 < len(batches) else None
            with tr.overlap() as scope:
                # consumer: issue batch k's centralized BP.  Under the fused
                # path this dispatches asynchronously and returns futures,
                # so the producer below genuinely overlaps it.
                with scope.lane("bp"):
                    stats.append(orch.apply_update(vb, results, order))
                # producer: prefetch batch k+1 against the just-issued
                # update's (future) parameters — strict mode — or against
                # the cached epoch parameters the nodes already hold.
                if nxt is not None:
                    self._enqueue(self._produce(nxt, node_by_id, scope))
            self._queue.popleft()

        if completes:
            orch._epoch += 1
        return orch._finalize_epoch_stats(stats)


def pipelined_train_epoch(orch, *, start_batch: int = 0,
                          max_batches: int | None = None) -> List:
    """Run one epoch of ``orch`` through the double-buffered engine."""
    return PipelinedEpochEngine(orch).run_epoch(start_batch=start_batch,
                                                max_batches=max_batches)
