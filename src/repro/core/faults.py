"""Fault injection and recovery for the traversal protocol.

TL's whole value proposition is losslessness: the orchestrator plans
sequential node visits and runs centralized BP, so one dropped or slow node
mid-traversal would stall or corrupt the entire virtual batch — a failure
mode the paper never faces but a production deployment faces constantly
(cf. SplitFed under packet loss, Tram-FL's route re-planning).  This module
makes TL recover *bit-identically* instead of degrading:

* :class:`FaultSpec` / :class:`FaultInjector` — seeded, per-visit-attempt
  fault decisions (drop with probability ``drop_prob``, straggle with
  probability ``straggle_prob`` at a ``straggle_factor`` clock multiplier).
  Decisions are keyed by ``(epoch, batch, node, attempt)`` and derived from
  a counter-based RNG, so they are **order-independent**: the serial and
  pipelined engines draw identical faults for the same visit, and a retry
  (attempt+1) is a fresh draw — determinism without global RNG state.
* :class:`RecoveryPolicy` — how the orchestrator reacts: per-visit retries
  with (simulated-clock) backoff, failover to a replica node after
  ``retries_before_failover`` failed attempts, and mid-epoch traversal
  re-planning: once a node has accumulated ``evict_after`` failures in an
  epoch, its later segments route straight to the replica without burning
  retries on the dead primary.
* :class:`VisitDropped` / :class:`UnrecoverableFault` — the transport raises
  the former at the end of a dropped fault lane (the attempt's bytes and
  clock are charged: the payload burned wire time before it was lost); the
  orchestrator raises the latter when the policy is exhausted and no
  replica exists, instead of silently assembling a partial virtual batch.

Why recovery is lossless: a visit payload is a pure function of
``(params, shard rows, batch_total)``.  A retry or a replica (holding the
same shard) therefore produces the *same* wire payload, and the reassembly
permutation — re-derived from the successfully collected segments — still
covers every virtual-batch row exactly once.  Faults move only the
simulated clock and the byte counters, never the arithmetic; the acceptance
grid in ``tests/test_faults.py`` asserts bit-equality of losses and params
against the fault-free run.

:func:`fault_expansion` is the analytic counterpart used by
``repro.core.runtime_model``: the expected clock multiplier of the
visit-phase under a fault spec (geometric retries × expected straggle
factor), so eq. 19 stays comparable to the transport-simulated clock.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

# attempt outcomes, in decision order (drop wins over straggle when both
# probabilities would fire — a dropped payload's speed is unobservable)
OK = "ok"
DROP = "drop"
STRAGGLE = "straggle"


class VisitDropped(Exception):
    """A visit attempt's payload was lost in transit (fault lane verdict).

    Raised by :meth:`repro.core.transport.Transport.fault_lane` *after* the
    attempt's transfers were charged — the bytes burned wire time even
    though the orchestrator never got a usable payload."""

    def __init__(self, key: Tuple):
        super().__init__(f"visit payload dropped: key={key}")
        self.key = key


class UnrecoverableFault(RuntimeError):
    """Retries and replica failover exhausted for one traversal segment."""


@dataclass(frozen=True)
class FaultSpec:
    """Configuration of the injected fault distribution (seeded)."""

    drop_prob: float = 0.0          # P[visit attempt's payload is lost]
    straggle_prob: float = 0.0      # P[attempt runs at straggle_factor]
    straggle_factor: float = 4.0    # clock multiplier for straggling visits
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError("drop_prob must be in [0, 1): with certainty-"
                             "loss no retry budget can ever succeed")
        if not 0.0 <= self.straggle_prob <= 1.0:
            raise ValueError("straggle_prob must be in [0, 1]")
        if self.straggle_factor < 1.0:
            raise ValueError("straggle_factor must be >= 1 (a multiplier)")


@dataclass(frozen=True)
class VisitOutcome:
    """One seeded decision for one visit attempt."""

    kind: str                       # OK | DROP | STRAGGLE
    factor: float = 1.0             # clock multiplier applied in the lane
    key: Tuple = ()


@dataclass(frozen=True)
class FaultEvent:
    """One recovery-relevant event, appended to ``Transport.fault_log`` (the
    injected verdicts) and ``TLOrchestrator.fault_log`` (the recovery
    actions: retry / failover / replan)."""

    key: Tuple                      # (epoch, batch_id, node_id, attempt)
    kind: str                       # DROP/STRAGGLE or "retry"/"failover"/...
    factor: float = 1.0
    clock_s: float = 0.0            # transport clock when the event fired
    nbytes: int = 0                 # bytes charged to the faulty attempt


class FaultInjector:
    """Order-independent seeded fault decisions, one per visit attempt.

    The decision for ``key = (epoch, batch_id, node_id, attempt)`` is drawn
    from ``np.random.default_rng((seed, *key))`` — a fresh counter-based
    stream per key — so the verdict depends only on the key, never on how
    many other visits were decided before it.  The serial loop, the
    double-buffered pipeline, and a killed-and-resumed run all see the same
    faults for the same visit.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    def decide(self, key: Tuple[int, ...]) -> VisitOutcome:
        s = self.spec
        if s.drop_prob == 0.0 and s.straggle_prob == 0.0:
            return VisitOutcome(OK, key=key)
        u = float(np.random.default_rng(
            (s.seed,) + tuple(int(k) for k in key)).random())
        if u < s.drop_prob:
            return VisitOutcome(DROP, key=key)
        if u < s.drop_prob + (1.0 - s.drop_prob) * s.straggle_prob:
            return VisitOutcome(STRAGGLE, factor=s.straggle_factor, key=key)
        return VisitOutcome(OK, key=key)


@dataclass(frozen=True)
class RecoveryPolicy:
    """How the orchestrator recovers from visit faults.

    * ``max_attempts`` — total attempts per segment (across primary and
      replica) before :class:`UnrecoverableFault`;
    * ``retries_before_failover`` — failed attempts on the primary before
      the segment is re-routed to the node's replica (if one exists);
    * ``evict_after`` — cumulative failures across the run after which the
      node is *evicted*: every later segment — mid-epoch and in all later
      epochs — routes straight to the replica (traversal re-planning),
      skipping the doomed primary entirely.  Eviction is permanent for the
      orchestrator's lifetime: a node that keeps dropping payloads is
      treated as dead, not flaky;
    * ``backoff_s`` — simulated-clock backoff before attempt ``a`` retries,
      charged as ``backoff_s * a`` (linear backoff on the virtual clock).
    """

    max_attempts: int = 8
    retries_before_failover: int = 2
    evict_after: int = 3
    backoff_s: float = 0.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")


@dataclass
class NodeHealth:
    """Per-node failure bookkeeping (run-scoped) backing the re-planning
    decisions.  Not checkpointed: a resumed run re-learns node health from
    scratch — the arithmetic is unaffected (recovery is lossless either
    way), only the retry-cost audit trail restarts."""

    failures: int = 0
    evicted: bool = False


def fault_expansion(drop_prob: float = 0.0, straggle_prob: float = 0.0,
                    straggle_factor: float = 1.0) -> float:
    """Expected clock multiplier of the visit phase under a fault spec.

    Every attempt (including the ones that end up dropped) pays an expected
    per-attempt factor of ``1 + straggle_prob * (straggle_factor - 1)``
    (conditional on not dropping — a dropped attempt's payload still burns
    one unit of wire time), and the attempt count is geometric with success
    probability ``1 - drop_prob``:

        E[cost] = E[attempts] * E[factor | attempt]
                = 1 / (1 - drop_prob)
                  * (drop_prob * 1 + (1 - drop_prob)
                     * (1 + straggle_prob * (straggle_factor - 1)))

    With no faults this is exactly 1.  Used by ``runtime_model.runtime_tl``
    so the analytic eq. 19 stays comparable to the fault-injected simulated
    clock."""
    if drop_prob >= 1.0:
        raise ValueError("drop_prob must be < 1")
    per_attempt = (drop_prob * 1.0
                   + (1.0 - drop_prob)
                   * (1.0 + straggle_prob * (straggle_factor - 1.0)))
    return per_attempt / (1.0 - drop_prob)
